//! Table 4 — basis-function pairs vs quadruples: the O(N²) pair data that
//! makes the O(N⁴) quadruple space streamable, plus constructor wall time.

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::constructor::{BlockPlan, PairList, SchwarzMode};
use matryoshka::util::Stopwatch;

fn main() {
    bh::header("Table 4 — pairs vs quadruples per performance system");
    println!(
        "{:<12} {:>8} {:>14} {:>14} {:>10} {:>8} {:>10}",
        "system", "pairs", "quadruples", "surviving", "screened%", "blocks", "build_s"
    );
    for name in ["chignolin", "dna", "crambin", "collagen", "trna", "pepsin"] {
        let (_, basis) = common::system(name);
        let sw = Stopwatch::start();
        let pairs = PairList::build_with_mode(&basis, 1e-10, SchwarzMode::Estimate);
        let plan = BlockPlan::build(&pairs, 1e-10, 64, true);
        let t = sw.elapsed_s();
        let s = plan.stats;
        println!(
            "{:<12} {:>8} {:>14} {:>14} {:>9.1}% {:>8} {:>10.3}",
            name,
            s.pairs,
            s.quadruples_total,
            s.quadruples_surviving,
            100.0 * s.quadruples_screened as f64 / s.quadruples_total.max(1) as f64,
            s.blocks,
            t
        );
        // the paper's point: quadruples dwarf pairs by orders of magnitude
        assert!(s.quadruples_total > 50 * s.pairs as u64, "{name}");
    }
    println!("\npair memory O(N^2) vs quadruple space O(N^4): ratio grows with system size");
}
