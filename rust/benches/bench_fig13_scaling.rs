//! Fig. 13 — scalability.
//!
//! Single-device curve: Fock-build time vs water-cluster size against the
//! surviving-ERI count (log-log slopes must track).  Multi-device weak
//! scaling: quadruple blocks are dependency-free, so sharding them across
//! W workers is exact; with one physical core we report *simulated* weak
//! scaling — per-shard isolated wall time, T_parallel = max over shards
//! (documented in DESIGN.md §Substitutions).

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::basis::build_basis;
use matryoshka::constructor::PairList;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::molecule::library;
use matryoshka::runtime::{EriBackend, EriEvalStrategy, NativeBackend};
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

fn main() {
    bh::header("Fig. 13a — single-device scaling (water clusters)");
    println!(
        "{:<8} {:>6} {:>12} {:>10} {:>11} {:>12}",
        "waters", "nbf", "quads", "time_s", "quads/s", "log-slope"
    );
    let sizes: &[usize] = if common::full_mode() { &[1, 2, 4, 8, 16, 32] } else { &[1, 2, 4, 8, 16] };
    let mut prev: Option<(u64, f64)> = None;
    for &n in sizes {
        let (_, basis) = common::system(&format!("water_cluster_{n}"));
        let d = common::test_density(basis.nbf);
        let mut engine = common::engine(basis.clone(), MatryoshkaConfig::default());
        common::warm_until_converged(&mut engine, &d, 3);
        let sw = Stopwatch::start();
        engine.two_electron(&d).expect("measured");
        let t = sw.elapsed_s();
        let quads = engine.plan().stats.quadruples_surviving;
        let slope = prev
            .map(|(pq, pt)| (t / pt).ln() / (quads as f64 / pq as f64).ln())
            .map(|s| format!("{s:>12.2}"))
            .unwrap_or_else(|| format!("{:>12}", "-"));
        println!("{:<8} {:>6} {:>12} {:>10.3} {:>11.0} {}", n, basis.nbf, quads, t, quads as f64 / t, slope);
        prev = Some((quads, t));
    }
    println!("(slope ≈ 1 ⇒ time tracks ERI count — the paper's stability claim)");

    bh::header("Fig. 13b — weak scaling (simulated multi-device, GluAla chains)");
    println!(
        "{:<9} {:>7} {:>12} {:>12} {:>12} {:>10}",
        "workers", "units", "quads", "T_1dev_s", "T_Wdev_s", "efficiency"
    );
    let worker_counts: &[usize] = if common::full_mode() { &[1, 2, 4] } else { &[1, 2] };
    for &workers in worker_counts {
        // weak scaling: problem grows with worker count
        let units = 2 * workers;
        let (_, basis) = common::system(&format!("gluala_{units}"));
        let d = common::test_density(basis.nbf);
        // 13b simulates multi-DEVICE scaling: both the full build and the
        // per-shard timings must be single-threaded so the efficiency
        // column compares like with like
        let mut engine =
            common::engine(basis.clone(), MatryoshkaConfig { threads: 1, ..Default::default() });
        common::warm_until_converged(&mut engine, &d, 3);

        let nblocks = engine.plan().blocks.len();
        // single-device time
        let sw = Stopwatch::start();
        engine.two_electron(&d).expect("t1");
        let t1 = sw.elapsed_s();
        // sharded: blocks are dependency-free; time each shard in isolation
        let mut shard_times = Vec::new();
        for w in 0..workers {
            let shard: Vec<usize> = (0..nblocks).filter(|b| b % workers == w).collect();
            let sw = Stopwatch::start();
            engine.build_g_for_blocks(&d, &shard).expect("shard");
            shard_times.push(sw.elapsed_s());
        }
        let t_par = shard_times.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{:<9} {:>7} {:>12} {:>12.3} {:>12.3} {:>9.2}%",
            workers,
            units,
            engine.plan().stats.quadruples_surviving,
            t1,
            t_par,
            100.0 * t1 / (workers as f64 * t_par)
        );
    }
    println!("(efficiency ≈ 100% ⇒ speedup grows ∝ devices, paper's multi-GPU claim)");

    bh::header("Fig. 13c — Fock-build thread scaling (real worker pool, benzene-scale+)");
    println!(
        "{:<16} {:>6} {:>8} {:>10} {:>10} {:>9}",
        "system", "nbf", "threads", "T_1_s", "T_N_s", "speedup"
    );
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let roster: &[&str] = if common::full_mode() {
        &["benzene", "water_cluster_8", "chignolin"]
    } else {
        &["benzene", "water_cluster_8"]
    };
    for name in roster {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let time_with = |threads: usize| {
            let config = MatryoshkaConfig { threads, ..Default::default() };
            let mut engine = common::engine(basis.clone(), config);
            engine.two_electron(&d).expect("warm"); // tuner + allocator warm
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured");
            sw.elapsed_s()
        };
        let t1 = time_with(1);
        let tn = time_with(hw);
        println!(
            "{:<16} {:>6} {:>8} {:>10.3} {:>10.3} {:>8.2}x",
            name,
            basis.nbf,
            hw,
            t1,
            tn,
            t1 / tn.max(1e-12)
        );
        // identical results guaranteed by the deterministic merge; on a
        // multi-core box the N-thread build must also be faster — with a
        // 10% noise allowance so scheduler jitter on small systems or
        // loaded machines doesn't abort the whole bench run
        if hw >= 2 {
            assert!(tn < t1 * 1.10, "{name}: {hw}-thread build not faster than 1-thread");
        }
    }
    println!("(thread count changes wall time, never results — bitwise-deterministic merge)");

    bh::header("Fig. 13d — memoized Hermite E/R tables vs recursive baseline (p/d classes)");
    println!(
        "{:<14} {:>6} {:>7} {:>11} {:>11} {:>9}",
        "class", "ncomp", "quads", "recur_s", "tables_s", "speedup"
    );
    let mol = library::by_name("water").expect("water");
    let basis = build_basis(&mol, "6-31g*").expect("6-31g* basis");
    let pairs = PairList::build(&basis, 1e-14);
    // first pair of each pair-class, by the clustered class ranges
    let pair_of = |class: (u8, u8)| {
        pairs
            .class_ranges
            .iter()
            .find(|(c, _)| *c == class)
            .map(|(_, r)| &pairs.pairs[r.start])
            .expect("pair class present in water/6-31G*")
    };
    let reps = if common::full_mode() { 20 } else { 6 };
    for (bra_c, ket_c) in [((1, 1), (1, 1)), ((2, 0), (0, 0)), ((2, 2), (1, 1)), ((2, 2), (2, 2))] {
        let (bra, ket) = (pair_of(bra_c), pair_of(ket_c));
        let class = (bra_c.0, bra_c.1, ket_c.0, ket_c.1);
        let time_with = |strategy: EriEvalStrategy| {
            let backend = NativeBackend::with_options(pairs.kpair, strategy);
            let variant = backend.manifest().ladder(class)[1].clone(); // mid rung
            let (b, kb, kk) = (variant.batch, variant.kpair_bra, variant.kpair_ket);
            // replicate one real quad across every batch row
            let mut bp = vec![0.0; b * kb * 5];
            let mut bg = vec![0.0; b * 6];
            let mut kp = vec![0.0; b * kk * 5];
            let mut kg = vec![0.0; b * 6];
            for r in 0..b {
                bp[r * kb * 5..(r + 1) * kb * 5].copy_from_slice(&bra.prim);
                kp[r * kk * 5..(r + 1) * kk * 5].copy_from_slice(&ket.prim);
                bg[r * 6..(r + 1) * 6].copy_from_slice(&bra.geom);
                kg[r * 6..(r + 1) * 6].copy_from_slice(&ket.geom);
            }
            backend.execute_eri(&variant, &bp, &bg, &kp, &kg).expect("warm");
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let sw = Stopwatch::start();
                backend.execute_eri(&variant, &bp, &bg, &kp, &kg).expect("measured");
                best = best.min(sw.elapsed_s());
            }
            (best, variant.ncomp, b)
        };
        let (t_rec, ncomp, b) = time_with(EriEvalStrategy::Recursion);
        let (t_tab, _, _) = time_with(EriEvalStrategy::Tables);
        println!(
            "{:<14} {:>6} {:>7} {:>11.5} {:>11.5} {:>8.2}x",
            format!("{class:?}"),
            ncomp,
            b,
            t_rec,
            t_tab,
            t_rec / t_tab.max(1e-12)
        );
        // the memoized tables must beat the recursion on d-heavy classes
        // (10% noise allowance, as in 13c)
        if class.0 == 2 && class.1 == 2 {
            assert!(
                t_tab < t_rec * 1.10,
                "{class:?}: table evaluator not faster than the recursive baseline"
            );
        }
    }
    println!("(one (axis, primitive-pair) E-table serves all ncomp component quadruples)");

    bh::header("Fig. 13e — multi-process dispatch (local workers vs in-process)");
    println!(
        "{:<16} {:>6} {:>10} {:>12} {:>12} {:>8}",
        "system", "nbf", "dispatch", "T_inproc_s", "T_disp_s", "ratio"
    );
    // real subprocesses over the stdio wire; bitwise-equal G is asserted,
    // wall time is informational (one host pays serialization + IPC for
    // fault isolation — the win is cross-host scale, not local speed)
    let dispatch_roster: &[&str] =
        if common::full_mode() { &["benzene", "water_cluster_8"] } else { &["benzene"] };
    for name in dispatch_roster {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut inproc = common::engine(basis.clone(), MatryoshkaConfig::default());
        inproc.two_electron(&d).expect("warm");
        let sw = Stopwatch::start();
        let g_ref = inproc.two_electron(&d).expect("in-process");
        let t_in = sw.elapsed_s();

        for workers in [1usize, 2] {
            let config = MatryoshkaConfig {
                dispatch: matryoshka::dispatch::DispatchConfig {
                    mode: matryoshka::dispatch::DispatchMode::Local(workers),
                    worker_bin: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))),
                    ..Default::default()
                },
                ..Default::default()
            };
            let mut engine = common::engine(basis.clone(), config);
            engine.two_electron(&d).expect("warm (spawns workers)");
            let sw = Stopwatch::start();
            let g = engine.two_electron(&d).expect("dispatched");
            let t_disp = sw.elapsed_s();
            assert_eq!(g_ref.data(), g.data(), "{name}: dispatched G diverged");
            println!(
                "{:<16} {:>6} {:>10} {:>12.3} {:>12.3} {:>7.2}x",
                name,
                basis.nbf,
                format!("local:{workers}"),
                t_in,
                t_disp,
                t_disp / t_in.max(1e-12)
            );
        }
    }
    println!("(G asserted bitwise-identical across process boundaries — the dispatch guarantee)");

    bh::header("Fig. 13f — graph-compiled kernels vs memoized tables (per class)");
    println!(
        "{:<14} {:>6} {:>7} {:>11} {:>11} {:>9}",
        "class", "ncomp", "quads", "tables_s", "kernels_s", "speedup"
    );
    // SoA straight-line kernels against the table interpreter on the same
    // chunks: the d-heavy classes are where the unrolled recurrences and
    // the batch-major inner loop pay off.  Rows also land in
    // BENCH_fig13.json for machine consumption.
    use matryoshka::trace::json::Value;
    use matryoshka::trace::snapshot::row;
    let mut bench_rows: Vec<Value> = Vec::new();
    for (bra_c, ket_c) in [
        ((0, 0), (0, 0)),
        ((1, 1), (0, 0)),
        ((1, 1), (1, 1)),
        ((2, 2), (0, 0)),
        ((2, 2), (1, 1)),
        ((2, 2), (2, 2)),
    ] {
        let (bra, ket) = (pair_of(bra_c), pair_of(ket_c));
        let class = (bra_c.0, bra_c.1, ket_c.0, ket_c.1);
        let time_with = |strategy: EriEvalStrategy| {
            let backend = NativeBackend::with_options(pairs.kpair, strategy);
            let variant = backend.manifest().ladder(class)[1].clone(); // mid rung
            let (b, kb, kk) = (variant.batch, variant.kpair_bra, variant.kpair_ket);
            let mut bp = vec![0.0; b * kb * 5];
            let mut bg = vec![0.0; b * 6];
            let mut kp = vec![0.0; b * kk * 5];
            let mut kg = vec![0.0; b * 6];
            for r in 0..b {
                bp[r * kb * 5..(r + 1) * kb * 5].copy_from_slice(&bra.prim);
                kp[r * kk * 5..(r + 1) * kk * 5].copy_from_slice(&ket.prim);
                bg[r * 6..(r + 1) * 6].copy_from_slice(&bra.geom);
                kg[r * 6..(r + 1) * 6].copy_from_slice(&ket.geom);
            }
            backend.execute_eri(&variant, &bp, &bg, &kp, &kg).expect("warm");
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let sw = Stopwatch::start();
                backend.execute_eri(&variant, &bp, &bg, &kp, &kg).expect("measured");
                best = best.min(sw.elapsed_s());
            }
            (best, variant.ncomp, b)
        };
        let (t_tab, ncomp, b) = time_with(EriEvalStrategy::Tables);
        let (t_ker, _, _) = time_with(EriEvalStrategy::Kernels);
        let speedup = t_tab / t_ker.max(1e-12);
        println!(
            "{:<14} {:>6} {:>7} {:>11.5} {:>11.5} {:>8.2}x",
            format!("{class:?}"),
            ncomp,
            b,
            t_tab,
            t_ker,
            speedup
        );
        bench_rows.push(row(vec![
            (
                "class",
                Value::Arr(
                    [class.0, class.1, class.2, class.3]
                        .iter()
                        .map(|&l| Value::Num(l as f64))
                        .collect(),
                ),
            ),
            ("ncomp", Value::Num(ncomp as f64)),
            ("batch", Value::Num(b as f64)),
            ("tables_s", Value::Num(t_tab)),
            ("kernels_s", Value::Num(t_ker)),
            ("speedup", Value::Num(speedup)),
        ]));
        // the generated straight-line code must not lose to the
        // interpreter on the heaviest class (10% noise allowance)
        if class == (2, 2, 2, 2) {
            assert!(
                t_ker < t_tab * 1.10,
                "{class:?}: graph-compiled kernel not faster than the table interpreter"
            );
        }
    }
    let mut snap = bh::bench_snapshot("fig13", "kernels_vs_tables");
    snap.table("rows", bench_rows);
    snap.write(std::path::Path::new("BENCH_fig13.json")).expect("write BENCH_fig13.json");
    println!("(rows written to BENCH_fig13.json; straight-line SoA kernels vs table interpreter)");
}
