//! Fig. 11 + §8.3.3 — Graph Compiler effect.
//!
//! Register-spill analog: peak live intermediates of the scheduled
//! straight-line kernel (manifest `max_live`); occupancy analog: its
//! reciprocal, normalized.  Wall-clock: greedy-path vs random-path kernels
//! on identical workloads (the paper reports 1.42x for Crambin).

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::runtime::Manifest;
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

fn main() {
    // real Graph-Compiler statistics need compiled artifacts; the native
    // synthetic catalog keeps the bench runnable (greedy == random there)
    let manifest: Manifest = common::catalog();

    bh::header("Fig. 11a — live-set (register-pressure analog) per class");
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>14} {:>14}",
        "class", "greedy_live", "random_live", "reduction", "greedy_occup.", "random_occup."
    );
    for class in manifest.classes() {
        let Some(g) = manifest.ladder(class).first().copied().cloned() else { continue };
        let Some(r) = manifest.random_variant(class).cloned() else { continue };
        // occupancy proxy: schedulable contexts limited by live registers
        let occ = |live: usize| 1.0 / live as f64;
        println!(
            "{:<10} {:>12} {:>12} {:>9.2}x {:>14.5} {:>14.5}",
            format!("{class:?}"),
            g.max_live,
            r.max_live,
            r.max_live as f64 / g.max_live as f64,
            occ(g.max_live),
            occ(r.max_live)
        );
        // greedy optimizes reuse (op count) first; live set usually but
        // not always shrinks — the schedule length is the hard guarantee
    }

    bh::header("Fig. 11a' — scheduled op count (generated-code size) per class");
    for class in manifest.classes() {
        let Some(g) = manifest.ladder(class).first().copied().cloned() else { continue };
        let Some(r) = manifest.random_variant(class).cloned() else { continue };
        println!(
            "{:<16} greedy_vrr {:>5}  random_vrr {:>5}  saved {:>5.1}%",
            format!("{class:?}"),
            g.n_vrr,
            r.n_vrr,
            100.0 * (r.n_vrr as f64 - g.n_vrr as f64) / r.n_vrr.max(1) as f64
        );
        assert!(g.n_vrr <= r.n_vrr, "greedy schedule must not be longer");
    }

    bh::header("Fig. 11b / §8.3.3 — greedy vs random path kernels, wall clock");
    for name in ["chignolin", "crambin"] {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut times = Vec::new();
        for greedy in [true, false] {
            let config = MatryoshkaConfig {
                greedy_path: greedy,
                autotune: false,
                fixed_batch: 512, // random artifacts exist at b512
                ..Default::default()
            };
            let mut engine = common::engine(basis.clone(), config);
            engine.two_electron(&d).expect("warm-up");
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured");
            times.push(sw.elapsed_s());
        }
        println!(
            "{}",
            bh::speedup_row(&format!("{name}: random-path vs greedy-path"), times[1], times[0])
        );
    }
}
