//! Fig. 14 — end-to-end comparison: Matryoshka vs the CPU-centric
//! reference (Libint/PySCF stand-in) vs the static-parallelism QUICK
//! analog, across the performance systems.
//!
//! Measurement unit: one direct Fock build (warm kernels); the paper caps
//! iteration counts to compare the same work, we compare the per-iteration
//! unit directly.  The reference engine — like PySCF in the paper — is
//! "insufficient for producing results for large-sized molecules" and is
//! skipped beyond crambin unless FULL=1.

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::{MatryoshkaConfig, ReferenceEngine};
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

fn main() {
    let full = common::full_mode();
    let systems: Vec<&str> = if full {
        vec!["chignolin", "dna", "crambin", "collagen", "trna", "pepsin"]
    } else {
        vec!["chignolin", "dna", "crambin", "collagen"]
    };
    let reference_ok = |name: &str| full || matches!(name, "chignolin" | "dna" | "crambin");

    bh::header("Fig. 14 — end-to-end Fock build: reference vs QUICK-analog vs Matryoshka");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "system", "reference_s", "static_s", "matryoshka_s", "vs reference", "vs static"
    );
    for name in &systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);

        let mut m = common::engine(basis.clone(), MatryoshkaConfig::default());
        common::warm_until_converged(&mut m, &d, 4);
        let sw = Stopwatch::start();
        m.two_electron(&d).expect("measured");
        let t_m = sw.elapsed_s();

        let mut s = common::engine(
            basis.clone(),
            MatryoshkaConfig { autotune: false, fixed_batch: 128, clustered: true, ..Default::default() },
        );
        s.two_electron(&d).expect("warm");
        let sw = Stopwatch::start();
        s.two_electron(&d).expect("measured");
        let t_s = sw.elapsed_s();

        let t_ref = if reference_ok(name) {
            let mut r = ReferenceEngine::new(basis.clone(), 1e-10);
            let sw = Stopwatch::start();
            r.two_electron(&d).expect("reference");
            Some(sw.elapsed_s())
        } else {
            None
        };

        println!(
            "{:<12} {:>12} {:>12.3} {:>12.3} {:>14} {:>13.2}x",
            name,
            t_ref.map(|t| format!("{t:.3}")).unwrap_or_else(|| "(> budget)".into()),
            t_s,
            t_m,
            t_ref
                .map(|t| format!("{:.2}x", t / t_m))
                .unwrap_or_else(|| "-".into()),
            t_s / t_m
        );
        if let Some(t) = t_ref {
            assert!(t_m < t, "{name}: matryoshka must beat the CPU baseline");
        }
    }
    println!("\n(speedup > 1x against both baselines on every system reproduces Fig. 14's shape)");
}
