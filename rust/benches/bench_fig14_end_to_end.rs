//! Fig. 14 — end-to-end comparison: Matryoshka vs the CPU-centric
//! reference (Libint/PySCF stand-in) vs the static-parallelism QUICK
//! analog, across the performance systems.
//!
//! Measurement unit: one direct Fock build (warm kernels); the paper caps
//! iteration counts to compare the same work, we compare the per-iteration
//! unit directly.  The reference engine — like PySCF in the paper — is
//! "insufficient for producing results for large-sized molecules" and is
//! skipped beyond crambin unless FULL=1.

mod common;

use matryoshka::basis::build_basis;
use matryoshka::bench_harness as bh;
use matryoshka::engines::{IncrementalMode, MatryoshkaConfig, ReferenceEngine};
use matryoshka::molecule::library;
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};
use matryoshka::util::Stopwatch;

fn main() {
    let full = common::full_mode();
    let systems: Vec<&str> = if full {
        vec!["chignolin", "dna", "crambin", "collagen", "trna", "pepsin"]
    } else {
        vec!["chignolin", "dna", "crambin", "collagen"]
    };
    let reference_ok = |name: &str| full || matches!(name, "chignolin" | "dna" | "crambin");

    bh::header("Fig. 14 — end-to-end Fock build: reference vs QUICK-analog vs Matryoshka");
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>14} {:>14}",
        "system", "reference_s", "static_s", "matryoshka_s", "vs reference", "vs static"
    );
    for name in &systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);

        let mut m = common::engine(basis.clone(), MatryoshkaConfig::default());
        common::warm_until_converged(&mut m, &d, 4);
        let sw = Stopwatch::start();
        m.two_electron(&d).expect("measured");
        let t_m = sw.elapsed_s();

        let mut s = common::engine(
            basis.clone(),
            MatryoshkaConfig { autotune: false, fixed_batch: 128, clustered: true, ..Default::default() },
        );
        s.two_electron(&d).expect("warm");
        let sw = Stopwatch::start();
        s.two_electron(&d).expect("measured");
        let t_s = sw.elapsed_s();

        let t_ref = if reference_ok(name) {
            let mut r = ReferenceEngine::new(basis.clone(), 1e-10);
            let sw = Stopwatch::start();
            r.two_electron(&d).expect("reference");
            Some(sw.elapsed_s())
        } else {
            None
        };

        println!(
            "{:<12} {:>12} {:>12.3} {:>12.3} {:>14} {:>13.2}x",
            name,
            t_ref.map(|t| format!("{t:.3}")).unwrap_or_else(|| "(> budget)".into()),
            t_s,
            t_m,
            t_ref
                .map(|t| format!("{:.2}x", t / t_m))
                .unwrap_or_else(|| "-".into()),
            t_s / t_m
        );
        if let Some(t) = t_ref {
            assert!(t_m < t, "{name}: matryoshka must beat the CPU baseline");
        }
    }
    println!("\n(speedup > 1x against both baselines on every system reproduces Fig. 14's shape)");

    bh::header("Fig. 14b — incremental (ΔD-screened) vs full-rebuild SCF");
    println!(
        "{:<18} {:>6} {:>6} {:>18} {:>10} {:>12} {:>12}",
        "mode", "iters", "conv", "energy_ha", "fock_s", "chunks_tot", "chunks_last"
    );
    // Full SCF to convergence, same molecule/basis/tolerances — the only
    // difference is the incremental flag.  The ΔD-weighted screen shrinks
    // the executed chunk set as the density settles; the final energies
    // must agree to the pinning tolerance (1e-9 Ha, the acceptance bar).
    let mol = library::by_name("water").expect("water");
    let basis = build_basis(&mol, "6-31g*").expect("6-31g* basis");
    use matryoshka::trace::json::Value;
    use matryoshka::trace::snapshot::row;
    let mut bench_rows: Vec<Value> = Vec::new();
    let mut energies: Vec<f64> = Vec::new();
    let mut fock_walls: Vec<f64> = Vec::new();
    for (label, mode) in [
        ("full-rebuild", IncrementalMode::Off),
        ("incremental", IncrementalMode::On),
        ("incremental:8", IncrementalMode::Every(8)),
    ] {
        let config = MatryoshkaConfig { incremental: mode, ..Default::default() };
        let mut eng = common::engine(basis.clone(), config);
        let sw = Stopwatch::start();
        let res = run_rhf(&mol, &basis, &mut eng, &ScfOptions::default()).expect("scf");
        let wall = sw.elapsed_s();
        let fock_s = eng.metrics.incremental_seconds + eng.metrics.full_seconds;
        let trace = eng.fock_trace();
        let chunks_total: u64 = trace.iter().map(|s| s.chunks_executed).sum();
        let chunks_last = trace.last().map(|s| s.chunks_executed).unwrap_or(0);
        println!(
            "{:<18} {:>6} {:>6} {:>18.9} {:>10.3} {:>12} {:>12}",
            label, res.iterations, res.converged, res.energy, fock_s, chunks_total, chunks_last
        );
        bench_rows.push(row(vec![
            ("mode", Value::Str(label.to_string())),
            ("iterations", Value::Num(res.iterations as f64)),
            ("converged", Value::Bool(res.converged)),
            ("energy_ha", Value::Num(res.energy)),
            ("scf_wall_s", Value::Num(wall)),
            ("fock_wall_s", Value::Num(fock_s)),
            ("incremental_builds", Value::Num(eng.metrics.incremental_builds as f64)),
            ("full_builds", Value::Num(eng.metrics.full_builds as f64)),
            ("chunks_total", Value::Num(chunks_total as f64)),
            ("chunks_last", Value::Num(chunks_last as f64)),
        ]));
        assert!(res.converged, "{label}: SCF did not converge");
        energies.push(res.energy);
        fock_walls.push(fock_s);
    }
    for e in energies.iter().skip(1) {
        assert!(
            (e - energies[0]).abs() <= 1e-9,
            "incremental energy drifted {:.3e} Ha from the full-rebuild path",
            (e - energies[0]).abs()
        );
    }
    let mut snap = bh::bench_snapshot("fig14", "incremental_vs_full_scf");
    snap.ctx_str("molecule", "water").ctx_str("basis", "6-31g*");
    snap.table("rows", bench_rows);
    snap.write(std::path::Path::new("BENCH_fig14.json")).expect("write BENCH_fig14.json");
    println!(
        "\n(energies pinned within 1e-9 Ha of the full-rebuild path; \
         fock wall {:.3}s full vs {:.3}s incremental — rows in BENCH_fig14.json)",
        fock_walls[0], fock_walls[1]
    );
}
