#![allow(dead_code)] // shared across bench targets; each uses a subset

//! Shared helpers for the bench targets.
//!
//! Benches default to the native backend (no artifacts, no XLA) so
//! `cargo bench` works on a bare checkout.  Set `MATRYOSHKA_BACKEND=pjrt`
//! (with `--features pjrt` and a compiled artifacts/ directory) to measure
//! the PJRT path instead; `MATRYOSHKA_THREADS=N` pins the Fock worker
//! count (default: all cores); `MATRYOSHKA_PIPELINE=staged|lockstep`
//! overrides the worker pipeline mode (default: staged);
//! `MATRYOSHKA_LADDER=elastic|fixed` overrides the batch-ladder mode
//! (default: elastic); `MATRYOSHKA_ERI_STRATEGY=kernels|tables|recursion`
//! overrides the native chunk evaluator (default: kernels — the
//! graph-compiled per-class kernels); `MATRYOSHKA_DIGEST=gemm|scatter`
//! overrides the digestion strategy (default: gemm — the tiled
//! block-GEMM contraction).

use std::path::{Path, PathBuf};

use matryoshka::basis::{build_basis, BasisSet};
use matryoshka::constructor::SchwarzMode;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::fock::DigestStrategy;
use matryoshka::linalg::Matrix;
use matryoshka::molecule::{library, Molecule};
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::{
    BackendKind, EriBackend, EriEvalStrategy, LadderMode, Manifest, NativeBackend,
};

pub fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Variant catalog for per-class cost-model reporting: the real artifact
/// manifest when one is compiled, else the native synthetic catalog
/// (honoring `MATRYOSHKA_LADDER`, so reported rungs match the engines').
/// A manifest that exists but fails to parse is a real error — never
/// silently report synthetic numbers as artifact statistics.
pub fn catalog() -> Manifest {
    match artifact_dir() {
        Some(dir) => Manifest::load(&dir).expect("artifacts/manifest.txt exists but failed to parse"),
        None => NativeBackend::with_ladder(matryoshka::constructor::KPAIR, env_ladder())
            .manifest()
            .clone(),
    }
}

/// The `MATRYOSHKA_LADDER` override, defaulting to the config default.
fn env_ladder() -> LadderMode {
    match std::env::var("MATRYOSHKA_LADDER") {
        Ok(l) => LadderMode::parse(&l).expect("MATRYOSHKA_LADDER"),
        Err(_) => LadderMode::default(),
    }
}

/// The `MATRYOSHKA_ERI_STRATEGY` override, defaulting to the config
/// default (the graph-compiled kernels).
pub fn env_strategy() -> EriEvalStrategy {
    match std::env::var("MATRYOSHKA_ERI_STRATEGY") {
        Ok(s) => EriEvalStrategy::parse(&s).expect("MATRYOSHKA_ERI_STRATEGY"),
        Err(_) => EriEvalStrategy::default(),
    }
}

/// The `MATRYOSHKA_DIGEST` override, defaulting to the config default
/// (the tiled block-GEMM contraction).
pub fn env_digest() -> DigestStrategy {
    match std::env::var("MATRYOSHKA_DIGEST") {
        Ok(s) => DigestStrategy::parse(&s).expect("MATRYOSHKA_DIGEST"),
        Err(_) => DigestStrategy::default(),
    }
}

pub fn system(name: &str) -> (Molecule, BasisSet) {
    let mol = library::by_name(name).expect("known molecule");
    let basis = build_basis(&mol, "sto-3g").expect("basis");
    (mol, basis)
}

/// SCF-like symmetric test density (deterministic; not iteration-dependent
/// so single-Fock-build timings are comparable across engines).
pub fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.4 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

/// Build an engine with the bench defaults (estimate Schwarz for speed,
/// backend/threads from the environment — see module docs).
/// `MATRYOSHKA_THREADS` only applies when the bench left `threads` at the
/// default 0 — benches that pin a thread count (e.g. the Fig. 13 scaling
/// sections, which *measure* thread counts) keep their explicit setting.
pub fn engine(basis: BasisSet, mut config: MatryoshkaConfig) -> MatryoshkaEngine {
    if let Ok(p) = std::env::var("MATRYOSHKA_PIPELINE") {
        config.pipeline = PipelineMode::parse(&p).expect("MATRYOSHKA_PIPELINE");
    }
    config.ladder = env_ladder();
    config.eri_strategy = env_strategy();
    config.digest = env_digest();
    engine_pinned_config(basis, config)
}

/// Like [`engine`], but the caller's `pipeline` AND `ladder` choices are
/// final — the env overrides are ignored.  For benches that *measure*
/// those modes (fig9e pipeline A/B, fig12b ladder A/B) or depend on one
/// (fig10's fixed-rung padding baseline), where an env override would
/// silently mislabel the rows.
pub fn engine_pinned_config(basis: BasisSet, config: MatryoshkaConfig) -> MatryoshkaEngine {
    engine_pinned_pipeline(basis, config)
}

/// Like [`engine`], but the caller's `pipeline` choice is final —
/// `MATRYOSHKA_PIPELINE` is ignored.  For benches that *measure* pipeline
/// modes (the Fig. 9e staged-vs-lockstep A/B), where an env override
/// would silently mislabel both rows.
pub fn engine_pinned_pipeline(basis: BasisSet, mut config: MatryoshkaConfig) -> MatryoshkaEngine {
    config.schwarz = SchwarzMode::Estimate;
    if config.threads == 0 {
        if let Ok(t) = std::env::var("MATRYOSHKA_THREADS") {
            config.threads = t.parse().expect("MATRYOSHKA_THREADS must be a number");
        }
    }
    let dir = if std::env::var("MATRYOSHKA_BACKEND").as_deref() == Ok("pjrt") {
        config.backend = BackendKind::Pjrt;
        artifact_dir().expect("MATRYOSHKA_BACKEND=pjrt needs artifacts/ (run `make artifacts`)")
    } else {
        PathBuf::from("unused")
    };
    MatryoshkaEngine::new(basis, &dir, config).expect("engine")
}

/// Warm an engine until the Workload Allocator has converged (or `cap`
/// builds): later builds then measure steady state with every variant the
/// tuner chose already compiled.
pub fn warm_until_converged(engine: &mut MatryoshkaEngine, d: &Matrix, cap: usize) {
    use matryoshka::scf::FockEngine;
    engine.two_electron(d).expect("warm-up build");
    if engine.tuner().all_converged() {
        return; // static configs: first build compiled everything needed
    }
    for _ in 1..cap {
        engine.two_electron(d).expect("warm-up build");
        if engine.tuner().all_converged() {
            break;
        }
    }
    // one more build so the final variant choices are all compiled
    engine.two_electron(d).expect("post-convergence warm-up");
}

/// `FULL=1 cargo bench` widens workloads to the complete paper roster.
pub fn full_mode() -> bool {
    std::env::var("FULL").map(|v| v == "1").unwrap_or(false)
}
