//! Fig. 12 — Workload Allocator: arithmetic intensity and throughput per
//! ERI class before vs after Algorithm-2 tuning.
//!
//! "Before" = every class pinned at the basic workload (smallest batch);
//! "after" = the allocator's converged choice.  Effective arithmetic
//! intensity folds the per-execution dispatch overhead the Combination
//! primitive amortizes: FLOP / (data bytes + fixed dispatch-equivalent).

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::runtime::Manifest;
use matryoshka::scf::FockEngine;

/// dispatch-equivalent bytes per PJRT execution (measured overhead folded
/// into the intensity model; see DESIGN.md §Hardware-Adaptation)
const DISPATCH_BYTES: f64 = 2.0e5;

fn main() {
    let manifest: Manifest = common::catalog();
    let name = if common::full_mode() { "crambin" } else { "chignolin" };
    let (_, basis) = common::system(name);
    let d = common::test_density(basis.nbf);

    // before: pinned to the basic workload (smallest variant)
    let mut before = common::engine(
        basis.clone(),
        MatryoshkaConfig { autotune: false, fixed_batch: 32, ..Default::default() },
    );
    before.two_electron(&d).expect("warm");
    before.metrics = Default::default();
    before.two_electron(&d).expect("before build");

    // after: Algorithm 2 online; measure once converged
    let mut after = common::engine(basis.clone(), MatryoshkaConfig::default());
    common::warm_until_converged(&mut after, &d, 5);
    after.metrics = Default::default();
    after.two_electron(&d).expect("after build");

    bh::header(&format!("Fig. 12 — allocator tuning on {name} (per ERI class)"));
    println!(
        "{:<16} {:>7} {:>12} {:>12} {:>11} {:>11} {:>8}",
        "class", "batch", "AI_before", "AI_after", "thr_before", "thr_after", "gain"
    );
    let mut total_b = 0.0;
    let mut total_a = 0.0;
    for (class, s_after) in &after.metrics.per_class {
        let s_before = before.metrics.per_class.get(class).copied().unwrap_or_default();
        let v = manifest.ladder(*class)[0];
        let chosen = after.tuner().tuner(*class).map(|t| t.current_batch()).unwrap_or(0);
        let ai = |batch: f64| {
            v.flops_per_quad * batch / (v.bytes_per_quad * batch + DISPATCH_BYTES)
        };
        println!(
            "{:<16} {:>7} {:>12.2} {:>12.2} {:>11.0} {:>11.0} {:>7.2}x",
            format!("{class:?}"),
            chosen,
            ai(32.0),
            ai(chosen as f64),
            s_before.throughput(),
            s_after.throughput(),
            s_after.throughput() / s_before.throughput().max(1.0)
        );
        total_b += s_before.seconds;
        total_a += s_after.seconds;
    }
    println!("{}", bh::speedup_row("total ERI wall (before vs after tuning)", total_b, total_a));
    // the native backend pays far less per-execution dispatch than PJRT,
    // so tuning gains are smaller there — tolerate measurement noise
    assert!(total_a < total_b * 1.10, "tuning must not be notably slower overall");
}
