//! Fig. 12 — Workload Allocator: arithmetic intensity and throughput per
//! ERI class before vs after Algorithm-2 tuning, plus the Workload
//! Allocator v2 A/B: intensity-derived elastic batch ladders vs the
//! one-size fixed ladder.
//!
//! "Before" = every class pinned at the basic workload (smallest batch);
//! "after" = the allocator's converged choice.  Effective arithmetic
//! intensity folds the per-execution dispatch overhead the Combination
//! primitive amortizes: FLOP / (data bytes + fixed dispatch-equivalent).

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::runtime::{LadderMode, Manifest};
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

/// dispatch-equivalent bytes per PJRT execution (measured overhead folded
/// into the intensity model; see DESIGN.md §Hardware-Adaptation)
const DISPATCH_BYTES: f64 = 2.0e5;

/// Fig. 12b — elastic vs fixed batch ladders, per ERI class.  Same
/// system, same pipeline, both tuned to convergence; the elastic ladder's
/// rungs are derived from each class's operational intensity, so
/// memory-bound s classes batch wide and compute-bound d classes narrow.
/// Asserts the elastic ladder is no slower than fixed per class (modulo
/// measurement noise) and overall.
fn ladder_section(name: &str, basis_name: &str) {
    println!("Fig. 12b — elastic vs fixed batch ladders on {name} / {basis_name}");
    let mol = matryoshka::molecule::library::by_name(name).expect("molecule");
    let basis = matryoshka::basis::build_basis(&mol, basis_name).expect("basis");
    let d = common::test_density(basis.nbf);

    let mut per_mode = Vec::new();
    let mut walls = Vec::new();
    for mode in [LadderMode::Fixed, LadderMode::Elastic] {
        let config = MatryoshkaConfig { ladder: mode, ..Default::default() };
        // pinned: this section measures the ladder modes themselves
        let mut engine = common::engine_pinned_pipeline(basis.clone(), config);
        common::warm_until_converged(&mut engine, &d, 5);
        engine.metrics = Default::default();
        let sw = Stopwatch::start();
        engine.two_electron(&d).expect("measured build");
        walls.push(sw.elapsed_s());
        let chosen: Vec<(String, usize, usize, f64)> = engine
            .metrics
            .per_class
            .iter()
            .map(|(class, s)| {
                let t = engine.tuner().tuner(*class);
                (
                    format!("{class:?}"),
                    t.map(|t| t.prior_batch).unwrap_or(0),
                    t.map(|t| t.current_batch()).unwrap_or(0),
                    s.seconds,
                )
            })
            .collect();
        per_mode.push(chosen);
    }

    println!(
        "{:<16} {:>11} {:>11} {:>11} {:>11} {:>9}",
        "class", "fixed_rung", "elast_prior", "elast_rung", "fixed_s", "elast_s"
    );
    for (fixed, elastic) in per_mode[0].iter().zip(&per_mode[1]) {
        assert_eq!(fixed.0, elastic.0, "class rosters must match");
        println!(
            "{:<16} {:>11} {:>11} {:>11} {:>11.4} {:>9.4}",
            fixed.0, fixed.2, elastic.1, elastic.2, fixed.3, elastic.3
        );
        // elastic no slower than fixed per class (generous tolerance:
        // per-class splits of one build carry scheduling noise)
        assert!(
            elastic.3 <= fixed.3 * 1.35 + 1e-3,
            "class {}: elastic {:.4}s vs fixed {:.4}s",
            fixed.0,
            elastic.3,
            fixed.3
        );
    }
    println!("{}", bh::speedup_row("Fock build wall (fixed vs elastic ladder)", walls[0], walls[1]));
    assert!(
        walls[1] <= walls[0] * 1.10,
        "elastic ladder must not be slower overall: {:.4}s vs {:.4}s",
        walls[1],
        walls[0]
    );
    println!();
}

fn main() {
    let manifest: Manifest = common::catalog();
    let name = if common::full_mode() { "crambin" } else { "chignolin" };
    let (_, basis) = common::system(name);
    let d = common::test_density(basis.nbf);

    // before: pinned to the basic workload (each ladder's bottom rung —
    // fixed_batch 1 snaps to the smallest variant of every class)
    let mut before = common::engine(
        basis.clone(),
        MatryoshkaConfig { autotune: false, fixed_batch: 1, ..Default::default() },
    );
    before.two_electron(&d).expect("warm");
    before.metrics = Default::default();
    before.two_electron(&d).expect("before build");

    // after: Algorithm 2 online; measure once converged
    let mut after = common::engine(basis.clone(), MatryoshkaConfig::default());
    common::warm_until_converged(&mut after, &d, 5);
    after.metrics = Default::default();
    after.two_electron(&d).expect("after build");

    bh::header(&format!("Fig. 12 — allocator tuning on {name} (per ERI class)"));
    println!(
        "{:<16} {:>7} {:>7} {:>12} {:>12} {:>11} {:>11} {:>8}",
        "class", "prior", "batch", "AI_before", "AI_after", "thr_before", "thr_after", "gain"
    );
    let mut total_b = 0.0;
    let mut total_a = 0.0;
    for (class, s_after) in &after.metrics.per_class {
        let s_before = before.metrics.per_class.get(class).copied().unwrap_or_default();
        let v = manifest.ladder(*class)[0];
        let tuner = after.tuner().tuner(*class);
        // the intensity prior the tuner was seeded on (v2) vs its
        // converged choice — also carried on every TunerObservation
        let prior = tuner.map(|t| t.prior_batch).unwrap_or(0);
        let chosen = tuner.map(|t| t.current_batch()).unwrap_or(0);
        let basic = v.batch as f64;
        let ai =
            |batch: f64| v.flops_per_quad * batch / (v.bytes_per_quad * batch + DISPATCH_BYTES);
        println!(
            "{:<16} {:>7} {:>7} {:>12.2} {:>12.2} {:>11.0} {:>11.0} {:>7.2}x",
            format!("{class:?}"),
            prior,
            chosen,
            ai(basic),
            ai(chosen as f64),
            s_before.throughput(),
            s_after.throughput(),
            s_after.throughput() / s_before.throughput().max(1.0)
        );
        total_b += s_before.seconds;
        total_a += s_after.seconds;
    }
    println!("{}", bh::speedup_row("total ERI wall (before vs after tuning)", total_b, total_a));
    // the native backend pays far less per-execution dispatch than PJRT,
    // so tuning gains are smaller there — tolerate measurement noise
    assert!(total_a < total_b * 1.10, "tuning must not be notably slower overall");
    println!();

    // Fig. 12b — the Workload Allocator v2 ladder A/B, on the synthetic
    // catalog's two regimes: an s/p protein chunk and a d-heavy system
    ladder_section(name, "sto-3g");
    ladder_section("water", "6-31g*");
}
