//! Fig. 9 — performance breakdown: Base → +Block Constructor → +Graph
//! Compiler → +Workload Allocator, cumulative Fock-build speedups.
//!
//! Measurement unit: one direct Fock build (the paper's ERI phase) on a
//! fixed density; kernel compilation is excluded via one warm-up build.
//! Default systems are the three smallest of the paper's performance set
//! (the unclustered Base config pays the full divergence penalty and
//! dominates wall time); FULL=1 runs all six.

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::fock::DigestStrategy;
use matryoshka::pipeline::PipelineMode;
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

/// 9e — staged-vs-lockstep pipeline A/B: the per-stage overlap report.
/// The staged executor's win is gather+digest CPU time hidden under ERI
/// execution; lockstep runs the identical schedule with the phases
/// strictly sequential inside each worker, so its hidden time is ≈ 0.
fn pipeline_overlap_section(systems: &[&str]) {
    println!("Fig. 9e — staged pipeline overlap (same schedule, phases overlapped vs lockstep)");
    println!(
        "{:<12} {:<9} {:>9} {:>10} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "system", "pipeline", "wall_s", "gather_s", "exec_s", "digest_s", "hidden_s", "xunit_s",
        "speedup"
    );
    for name in systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut lockstep_time = None;
        for mode in [PipelineMode::Lockstep, PipelineMode::Staged] {
            let config = MatryoshkaConfig { pipeline: mode, ..Default::default() };
            // pinned: this section measures the modes themselves, so the
            // MATRYOSHKA_PIPELINE env override must not relabel the rows
            let mut engine = common::engine_pinned_pipeline(basis.clone(), config);
            common::warm_until_converged(&mut engine, &d, 4);
            let baseline = engine.metrics.clone();
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured build");
            let wall = sw.elapsed_s();
            // metrics accumulate across builds; isolate the measured one
            let gather = engine.metrics.gather_seconds - baseline.gather_seconds;
            let digest = engine.metrics.digest_seconds - baseline.digest_seconds;
            let exec = engine.metrics.total_seconds() - baseline.total_seconds();
            let pipe_wall =
                engine.metrics.pipeline_wall_seconds - baseline.pipeline_wall_seconds;
            // cross-unit prefetch gathers hide under the previous unit's
            // tail drain by construction — reported separately
            let xunit =
                engine.metrics.prefetch_gather_seconds - baseline.prefetch_gather_seconds;
            let hidden = (gather + digest + exec - pipe_wall).max(0.0);
            let speedup = *lockstep_time.get_or_insert(wall) / wall;
            println!(
                "{:<12} {:<9} {:>9.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>9.3} {:>8.2}x",
                name,
                mode.name(),
                wall,
                gather,
                exec,
                digest,
                hidden,
                xunit,
                speedup
            );
            if mode == PipelineMode::Staged && hidden <= 0.0 {
                println!(
                    "  WARNING: staged build hid no gather/digest time — the cores are \
                     likely oversubscribed (try MATRYOSHKA_THREADS=<cores/2>)"
                );
            }
            if mode == PipelineMode::Lockstep {
                assert!(xunit == 0.0, "lockstep must never prefetch across units");
            }
        }
    }
    println!(
        "(hidden_s = gather + execute + digest − pipeline wall; xunit_s = cross-unit \
         prefetch gathers, a subset of hidden gather time; CPU-s across workers)"
    );
    println!();
}

/// 9f — gemm-vs-scatter digestion A/B: the identical schedule and ERI
/// panels, only the digestion stage swapped between the tiled block-GEMM
/// contraction and the per-quad 8-image scatter oracle.  Rows also land
/// in BENCH_fig9.json for machine consumption.
fn digest_strategy_section(systems: &[&str]) {
    println!("Fig. 9f — digestion wall A/B (tiled block GEMM vs per-quad scatter)");
    println!(
        "{:<12} {:<9} {:>9} {:>10} {:>9}",
        "system", "digest", "wall_s", "digest_s", "speedup"
    );
    use matryoshka::trace::json::Value;
    use matryoshka::trace::snapshot::row;
    let mut bench_rows: Vec<Value> = Vec::new();
    for name in systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut scatter_digest = None;
        for digest in [DigestStrategy::Scatter, DigestStrategy::Gemm] {
            let config = MatryoshkaConfig { digest, ..Default::default() };
            // pinned: this section measures the strategies themselves, so
            // the MATRYOSHKA_DIGEST env override must not relabel the rows
            let mut engine = common::engine_pinned_config(basis.clone(), config);
            common::warm_until_converged(&mut engine, &d, 4);
            let baseline = engine.metrics.clone();
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured build");
            let wall = sw.elapsed_s();
            let digest_s = engine.metrics.digest_seconds - baseline.digest_seconds;
            let speedup = *scatter_digest.get_or_insert(digest_s) / digest_s.max(1e-12);
            println!(
                "{:<12} {:<9} {:>9.3} {:>10.3} {:>8.2}x",
                name,
                digest.name(),
                wall,
                digest_s,
                speedup
            );
            bench_rows.push(row(vec![
                ("system", Value::Str(name.to_string())),
                ("digest", Value::Str(digest.name().to_string())),
                ("wall_s", Value::Num(wall)),
                ("digest_s", Value::Num(digest_s)),
                ("digest_speedup", Value::Num(speedup)),
            ]));
        }
    }
    let mut snap = bh::bench_snapshot("fig9", "digest_gemm_vs_scatter");
    snap.table("rows", bench_rows);
    snap.write(std::path::Path::new("BENCH_fig9.json")).expect("write BENCH_fig9.json");
    println!(
        "(rows written to BENCH_fig9.json; digest_s is CPU-s across workers — both \
         strategies digest the identical entry stream, G stays bitwise per strategy)"
    );
    println!();
}

fn main() {
    // the unclustered Base config costs O(100x) the clustered ones: the
    // default roster is chignolin (~2 min); FULL=1 runs all six (hours)
    let systems: Vec<&str> = if common::full_mode() {
        vec!["chignolin", "dna", "crambin", "collagen", "trna", "pepsin"]
    } else {
        vec!["chignolin"]
    };
    bh::header("Fig. 9 — component breakdown (one direct Fock build, warm kernels)");
    pipeline_overlap_section(&systems);
    digest_strategy_section(&systems);
    println!("config legend: base = no clustering + random-path kernels + static batch");

    for name in &systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut base_time = None;
        for (label, bc, gc, wa) in [
            ("base", false, false, false),
            ("+BC (Permutation)", true, false, false),
            ("+BC+GC (Deconstruction)", true, true, false),
            ("+BC+GC+WA (Combination)", true, true, true),
        ] {
            let config = MatryoshkaConfig::ablation(bc, gc, wa);
            let mut engine = common::engine(basis.clone(), config);
            common::warm_until_converged(&mut engine, &d, 4);
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured build");
            let t = sw.elapsed_s();
            let speedup = base_time.get_or_insert(t);
            println!(
                "{:<12} {:<26} {:>9.3}s  cumulative speedup {:>7.2}x  lane_util {:.3}",
                name,
                label,
                t,
                *speedup / t,
                engine.metrics.mean_lane_utilization()
            );
        }
        println!();
    }
}
