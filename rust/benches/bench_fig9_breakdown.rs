//! Fig. 9 — performance breakdown: Base → +Block Constructor → +Graph
//! Compiler → +Workload Allocator, cumulative Fock-build speedups.
//!
//! Measurement unit: one direct Fock build (the paper's ERI phase) on a
//! fixed density; kernel compilation is excluded via one warm-up build.
//! Default systems are the three smallest of the paper's performance set
//! (the unclustered Base config pays the full divergence penalty and
//! dominates wall time); FULL=1 runs all six.

mod common;

use matryoshka::bench_harness as bh;
use matryoshka::engines::MatryoshkaConfig;
use matryoshka::scf::FockEngine;
use matryoshka::util::Stopwatch;

fn main() {
    // the unclustered Base config costs O(100x) the clustered ones: the
    // default roster is chignolin (~2 min); FULL=1 runs all six (hours)
    let systems: Vec<&str> = if common::full_mode() {
        vec!["chignolin", "dna", "crambin", "collagen", "trna", "pepsin"]
    } else {
        vec!["chignolin"]
    };
    bh::header("Fig. 9 — component breakdown (one direct Fock build, warm kernels)");
    println!("config legend: base = no clustering + random-path kernels + static batch");

    for name in &systems {
        let (_, basis) = common::system(name);
        let d = common::test_density(basis.nbf);
        let mut base_time = None;
        for (label, bc, gc, wa) in [
            ("base", false, false, false),
            ("+BC (Permutation)", true, false, false),
            ("+BC+GC (Deconstruction)", true, true, false),
            ("+BC+GC+WA (Combination)", true, true, true),
        ] {
            let config = MatryoshkaConfig::ablation(bc, gc, wa);
            let mut engine = common::engine(basis.clone(), config);
            common::warm_until_converged(&mut engine, &d, 4);
            let sw = Stopwatch::start();
            engine.two_electron(&d).expect("measured build");
            let t = sw.elapsed_s();
            let speedup = base_time.get_or_insert(t);
            println!(
                "{:<12} {:<26} {:>9.3}s  cumulative speedup {:>7.2}x  lane_util {:.3}",
                name,
                label,
                t,
                *speedup / t,
                engine.metrics.mean_lane_utilization()
            );
        }
        println!();
    }
}
