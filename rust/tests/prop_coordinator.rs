//! Property tests over the coordinator's invariants: block-plan coverage
//! (routing), batching/tuner state, digestion algebra, and linear-algebra
//! identities — driven by the hand-built mini property framework.

use std::collections::HashSet;

use matryoshka::allocator::{AutoTuner, ClassTuner, TunerDecision};
use matryoshka::basis::build_basis;
use matryoshka::constructor::{BlockPlan, PairList, SchwarzMode};
use matryoshka::fock::digest_eri;
use matryoshka::integrals::boys;
use matryoshka::linalg::{eigh, solve, Matrix};
use matryoshka::molecule::{library, Atom, Molecule};
use matryoshka::prop_assert;
use matryoshka::testing::{check, Gen};

/// Random small closed-shell molecule of H/C/O atoms.
fn random_molecule(g: &mut Gen) -> Molecule {
    let n = g.usize_in(2, 6);
    let mut atoms = Vec::new();
    for _ in 0..n {
        let z = *g.pick(&[1u32, 6, 8]);
        atoms.push(Atom {
            z,
            pos: [g.f64_in(-4.0, 4.0), g.f64_in(-4.0, 4.0), g.f64_in(-4.0, 4.0)],
        });
    }
    // enforce even electron count by appending one H if needed
    let mut mol = Molecule::new("prop", atoms);
    if mol.nelec() % 2 == 1 {
        mol.atoms.push(Atom { z: 1, pos: [5.0, 5.0, 5.0] });
    }
    mol
}

#[test]
fn prop_block_plan_enumerates_each_unordered_quadruple_once() {
    check("plan-coverage", 12, |g| {
        let mol = random_molecule(g);
        let basis = build_basis(&mol, "sto-3g").map_err(|e| e.to_string())?;
        let tile = g.usize_in(2, 80);
        let clustered = g.bool();
        let pairs = PairList::build_with_mode(&basis, 0.0, SchwarzMode::Estimate);
        let plan = BlockPlan::build(&pairs, 0.0, tile, clustered);
        let p = pairs.len() as u64;
        prop_assert!(
            plan.stats.quadruples_surviving == p * (p + 1) / 2,
            "coverage {} != {}",
            plan.stats.quadruples_surviving,
            p * (p + 1) / 2
        );
        let mut seen = HashSet::new();
        for b in &plan.blocks {
            for &(x, y) in &b.quads {
                let key = if x >= y { (x, y) } else { (y, x) };
                prop_assert!(seen.insert(key), "duplicate quadruple {key:?}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocks_are_class_pure_and_canonical() {
    check("class-purity", 10, |g| {
        let mol = random_molecule(g);
        let basis = build_basis(&mol, "sto-3g").map_err(|e| e.to_string())?;
        let pairs = PairList::build_with_mode(&basis, 1e-9, SchwarzMode::Estimate);
        let plan = BlockPlan::build(&pairs, 1e-9, g.usize_in(4, 64), g.bool());
        for b in &plan.blocks {
            let (la, lb, lc, ld) = b.class;
            prop_assert!(la >= lb && lc >= ld && (la, lb) >= (lc, ld), "class {:?}", b.class);
            for &(p, q) in &b.quads {
                let bp = &pairs.pairs[p as usize];
                let kp = &pairs.pairs[q as usize];
                prop_assert!(
                    bp.class == (la, lb) && kp.class == (lc, ld),
                    "block class {:?} vs quad classes {:?} {:?}",
                    b.class,
                    bp.class,
                    kp.class
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tuner_batch_always_on_ladder_and_converges() {
    check("tuner-state", 60, |g| {
        let rungs = g.usize_in(1, 5);
        let mut ladder: Vec<usize> = (0..rungs).map(|i| 32 << i).collect();
        ladder.dedup();
        let mut t = ClassTuner::new((0, 0, 0, 0), ladder.clone()).unwrap();
        let mut observations = 0;
        while !t.converged && observations < 1000 {
            let quads = g.usize_in(1, 2048);
            let secs = g.f64_in(1e-6, 1e-2);
            let d = t.observe(quads, secs);
            prop_assert!(ladder.contains(&t.current_batch()), "off-ladder batch");
            if t.converged {
                prop_assert!(
                    matches!(d, TunerDecision::Converged | TunerDecision::Reverted),
                    "bad terminal decision {d:?}"
                );
            }
            observations += 1;
        }
        prop_assert!(t.converged, "tuner did not converge in 1000 observations");
        Ok(())
    });
}

#[test]
fn prop_disabled_autotuner_is_frozen() {
    let manifest = matryoshka::runtime::Manifest::parse(
        "a 0 0 0 0 32 9 9 1 0 1 0 5 9.0 8.0 greedy a\n\
         b 0 0 0 0 128 9 9 1 0 1 0 5 9.0 8.0 greedy b\n\
         c 0 0 0 0 512 9 9 1 0 1 0 5 9.0 8.0 greedy c\n",
        std::path::Path::new("/tmp"),
    )
    .unwrap();
    check("frozen-tuner", 40, |g| {
        let want = *g.pick(&[32usize, 128, 512, 777]);
        let mut at = AutoTuner::new(&manifest, false, want);
        let before = at.batch_for((0, 0, 0, 0));
        for _ in 0..g.usize_in(1, 20) {
            at.observe((0, 0, 0, 0), g.usize_in(1, 512), g.f64_in(1e-6, 1e-1));
        }
        prop_assert!(at.batch_for((0, 0, 0, 0)) == before, "frozen tuner moved");
        Ok(())
    });
}

#[test]
fn prop_digestion_is_linear_in_the_integral_value() {
    check("digest-linearity", 30, |g| {
        let n = g.usize_in(2, 8);
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = g.f64_in(-1.0, 1.0);
                *d.at_mut(i, j) = v;
                *d.at_mut(j, i) = v;
            }
        }
        let (i, j) = (g.usize_in(0, n - 1), g.usize_in(0, n - 1));
        let (k, l) = (g.usize_in(0, n - 1), g.usize_in(0, n - 1));
        let (i, j) = if i >= j { (i, j) } else { (j, i) };
        let (k, l) = if k >= l { (k, l) } else { (l, k) };
        let ((i, j), (k, l)) = if (i, j) >= (k, l) { ((i, j), (k, l)) } else { ((k, l), (i, j)) };
        let (v1, v2) = (g.f64_in(-2.0, 2.0), g.f64_in(-2.0, 2.0));

        let mut g1 = Matrix::zeros(n, n);
        digest_eri(&mut g1, &d, i, j, k, l, v1);
        digest_eri(&mut g1, &d, i, j, k, l, v2);
        let mut g2 = Matrix::zeros(n, n);
        digest_eri(&mut g2, &d, i, j, k, l, v1 + v2);
        prop_assert!(g1.diff_norm(&g2) < 1e-12, "digestion not linear: {}", g1.diff_norm(&g2));
        Ok(())
    });
}

#[test]
fn prop_eigh_reconstructs_random_symmetric_matrices() {
    check("eigh-reconstruction", 20, |g| {
        let n = g.usize_in(2, 10);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = g.f64_in(-3.0, 3.0);
                *m.at_mut(i, j) = v;
                *m.at_mut(j, i) = v;
            }
        }
        let e = eigh(&m);
        let mut vd = e.vectors.clone();
        for j in 0..n {
            for i in 0..n {
                *vd.at_mut(i, j) *= e.values[j];
            }
        }
        let rec = vd.matmul_transb(&e.vectors);
        prop_assert!(rec.diff_norm(&m) < 1e-9 * (n as f64), "||VWV^T - M|| = {}", rec.diff_norm(&m));
        // eigenvalues sorted
        for w in e.values.windows(2) {
            prop_assert!(w[0] <= w[1] + 1e-12, "unsorted eigenvalues");
        }
        Ok(())
    });
}

#[test]
fn prop_solve_residual_is_small_or_none() {
    check("solve-residual", 30, |g| {
        let n = g.usize_in(1, 8);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                *a.at_mut(i, j) = g.f64_in(-2.0, 2.0);
            }
            *a.at_mut(i, i) += 3.0; // diagonally dominant => solvable
        }
        let b = g.vec_f64(n, -1.0, 1.0);
        let x = solve(&a, &b).ok_or("unexpected singular")?;
        for i in 0..n {
            let mut r = -b[i];
            for j in 0..n {
                r += a.at(i, j) * x[j];
            }
            prop_assert!(r.abs() < 1e-9, "residual {r}");
        }
        Ok(())
    });
}

#[test]
fn prop_boys_recursion_holds_for_random_arguments() {
    check("boys-recursion", 100, |g| {
        let t = g.f64_in(0.0, 150.0);
        let mmax = g.usize_in(1, 10);
        let mut f = vec![0.0; mmax + 1];
        boys(mmax, t, &mut f);
        for m in 1..=mmax {
            let lhs = f[m - 1];
            let rhs = (2.0 * t * f[m] + (-t).exp()) / (2.0 * m as f64 - 1.0);
            prop_assert!(
                (lhs - rhs).abs() < 1e-10 * lhs.abs().max(1e-12),
                "recursion broken at m={m}, t={t}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_screening_is_monotone_in_threshold() {
    check("screening-monotone", 8, |g| {
        let n = g.usize_in(4, 12);
        let mol = library::water_cluster(n);
        let basis = build_basis(&mol, "sto-3g").map_err(|e| e.to_string())?;
        let pairs = PairList::build_with_mode(&basis, 0.0, SchwarzMode::Estimate);
        let t1 = 10f64.powf(g.f64_in(-14.0, -10.0));
        let t2 = t1 * 10f64.powf(g.f64_in(1.0, 4.0));
        let loose = BlockPlan::build(&pairs, t2, 64, true);
        let tight = BlockPlan::build(&pairs, t1, 64, true);
        prop_assert!(
            loose.stats.quadruples_surviving <= tight.stats.quadruples_surviving,
            "screening not monotone"
        );
        Ok(())
    });
}
