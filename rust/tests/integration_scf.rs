//! End-to-end integration: the Matryoshka engine (native backend, parallel
//! Fock pipeline) must reproduce the reference (serial per-quartet
//! McMurchie–Davidson) engine at SCF level.
//!
//! These tests run on every default build — the native backend needs no
//! artifacts.  The same assertions hold for the PJRT backend when built
//! with `--features pjrt` against a real xla-rs and a compiled
//! artifacts/ directory.

use std::path::Path;

use matryoshka::basis::build_basis;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine, ReferenceEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

/// Placeholder artifact dir: the native backend ignores it.
fn dir() -> &'static Path {
    Path::new("unused")
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

#[test]
fn g_matrix_matches_reference_engine_water() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut reference = ReferenceEngine::new(basis.clone(), 1e-14);
    let g_ref = reference.two_electron(&d).unwrap();

    let config = MatryoshkaConfig { threshold: 1e-14, ..Default::default() };
    let mut engine = MatryoshkaEngine::new(basis, dir(), config).unwrap();
    let g = engine.two_electron(&d).unwrap();

    let diff = g.diff_norm(&g_ref);
    assert!(diff < 1e-10, "G mismatch: ||dG|| = {diff:.3e}");
}

#[test]
fn all_ablation_configs_agree_on_g() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut reference = ReferenceEngine::new(basis.clone(), 1e-14);
    let g_ref = reference.two_electron(&d).unwrap();

    for (bc, gc, wa) in [
        (false, false, false),
        (true, false, false),
        (true, true, false),
        (true, true, true),
    ] {
        let mut config = MatryoshkaConfig::ablation(bc, gc, wa);
        config.threshold = 1e-14;
        let mut engine = MatryoshkaEngine::new(basis.clone(), dir(), config).unwrap();
        let g = engine.two_electron(&d).unwrap();
        let diff = g.diff_norm(&g_ref);
        assert!(diff < 1e-10, "ablation ({bc},{gc},{wa}): ||dG|| = {diff:.3e}");
    }
}

#[test]
fn water_scf_energy_matches_reference_engine_and_literature() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let opts = ScfOptions::default();

    let mut reference = ReferenceEngine::new(basis.clone(), 1e-12);
    let res_ref = run_rhf(&mol, &basis, &mut reference, &opts).unwrap();

    let config = MatryoshkaConfig { threshold: 1e-12, stored: true, ..Default::default() };
    let mut engine = MatryoshkaEngine::new(basis.clone(), dir(), config).unwrap();
    let res = run_rhf(&mol, &basis, &mut engine, &opts).unwrap();

    assert!(res_ref.converged && res.converged);
    // paper Table 3 requires <= 1e-5 agreement; we hold ourselves to 1e-9
    assert!(
        (res.energy - res_ref.energy).abs() < 1e-9,
        "matryoshka {} vs reference {}",
        res.energy,
        res_ref.energy
    );
    // literature RHF/STO-3G water ≈ −74.96 Ha
    assert!((res.energy + 74.96).abs() < 0.01, "water E = {:.7}", res.energy);
}

#[test]
fn stored_mode_matches_direct_mode() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut direct = MatryoshkaEngine::new(
        basis.clone(),
        dir(),
        MatryoshkaConfig { stored: false, ..Default::default() },
    )
    .unwrap();
    let mut stored = MatryoshkaEngine::new(
        basis,
        dir(),
        MatryoshkaConfig { stored: true, ..Default::default() },
    )
    .unwrap();

    let g_direct = direct.two_electron(&d).unwrap();
    let _warm = stored.two_electron(&d).unwrap(); // fills cache
    let g_cached = stored.two_electron(&d).unwrap(); // digest-only path
    assert!(g_direct.diff_norm(&g_cached) < 1e-12);
}

#[test]
fn sharded_g_build_sums_to_full_g() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut engine = MatryoshkaEngine::new(basis.clone(), dir(), MatryoshkaConfig::default()).unwrap();
    let g_full = engine.two_electron(&d).unwrap();

    let nblocks = engine.plan().blocks.len();
    let shard_a: Vec<usize> = (0..nblocks).filter(|i| i % 2 == 0).collect();
    let shard_b: Vec<usize> = (0..nblocks).filter(|i| i % 2 == 1).collect();
    let mut g_a = engine.build_g_for_blocks(&d, &shard_a).unwrap();
    let g_b = engine.build_g_for_blocks(&d, &shard_b).unwrap();
    g_a.add_scaled(&g_b, 1.0);
    assert!(g_a.diff_norm(&g_full) < 1e-11, "{}", g_a.diff_norm(&g_full));
}

#[test]
fn engine_metrics_and_stats_are_populated_after_a_build() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut engine = MatryoshkaEngine::new(basis, dir(), MatryoshkaConfig::default()).unwrap();
    engine.two_electron(&d).unwrap();

    let quads = engine.plan().stats.quadruples_surviving;
    assert_eq!(engine.metrics.total_real_quads(), quads);
    let rs = engine.runtime_stats();
    assert!(rs.executions > 0);
    assert!(rs.quadruple_slots >= quads);
    let util = engine.metrics.mean_lane_utilization();
    assert!(util > 0.0 && util <= 1.0, "lane utilization {util}");
}
