//! Staged-pipeline acceptance tests.
//!
//! * A staged build must be **bitwise** identical to a lockstep build
//!   (the pipeline changes when phases run, never what is digested, in
//!   which order, into which accumulator).
//! * A staged N-thread build must be bitwise identical to a staged
//!   1-thread build (the schedule and merge tree are thread-invariant).
//! * Schedule construction is pure: same inputs → identical schedule.
//! * Tail-chunk downshift is a schedule-build-time decision.
//! * A truncated stored-mode cache budget changes memory use, never the
//!   SCF result.
//! * A worker panic resurfaces with its original payload, not as a
//!   generic "dropped a merge unit" error.

use std::path::Path;

use matryoshka::basis::build_basis;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::{
    EriBackend, EriExecution, LadderMode, Manifest, NativeBackend, RuntimeStats, Variant,
};
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn engine(molecule: &str, basis_name: &str, config: MatryoshkaConfig) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

#[test]
fn staged_and_lockstep_builds_agree_bitwise_on_631gstar_water() {
    // 6-31G* lights up the d classes — the memory-heavy digestion path
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let mut g_by_mode = Vec::new();
    for mode in [PipelineMode::Staged, PipelineMode::Lockstep] {
        let config = MatryoshkaConfig { pipeline: mode, threads: 4, ..Default::default() };
        let mut e = engine("water", "6-31g*", config);
        g_by_mode.push(e.two_electron(&d).unwrap());
    }
    assert_eq!(
        g_by_mode[0].data(),
        g_by_mode[1].data(),
        "staged G diverged from lockstep G"
    );
}

#[test]
fn staged_build_is_bitwise_thread_invariant() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let build = |threads: usize| {
        let config = MatryoshkaConfig {
            pipeline: PipelineMode::Staged,
            threads,
            ..Default::default()
        };
        engine("water", "6-31g*", config).two_electron(&d).unwrap()
    };
    let g1 = build(1);
    for threads in [2, 5, 8] {
        let gn = build(threads);
        assert_eq!(
            g1.data(),
            gn.data(),
            "staged {threads}-thread build diverged from the staged 1-thread build"
        );
    }
}

#[test]
fn schedule_is_pure_and_tail_downshift_is_decided_at_build_time() {
    let e = engine("benzene", "sto-3g", MatryoshkaConfig::default());
    let a = e.build_schedule().unwrap();
    let b = e.build_schedule().unwrap();
    assert_eq!(a, b, "same engine state must produce the identical schedule");

    // downshift check: pin the rung at 512 on the FIXED ladder (elastic
    // ladders differ per class; the fixed 32/128/512 one keeps this
    // scenario exact).  Water's blocks all hold ≤ ~55 quads, so every
    // entry is a tail that must snap to a snug variant below the 512
    // rung — decided at build time
    let pinned = MatryoshkaConfig {
        autotune: false,
        fixed_batch: 512,
        ladder: LadderMode::Fixed,
        ..Default::default()
    };
    let w = engine("water", "sto-3g", pinned);
    let s = w.build_schedule().unwrap();
    let mut tails_downshifted = 0;
    for entry in &s.entries {
        assert!(entry.variant.batch >= entry.len(), "variant holds the chunk");
        assert_eq!(entry.rung, 512, "pinned tuner rung");
        let block_len = w.plan().blocks[entry.block].quads.len();
        if entry.end < block_len {
            assert_eq!(entry.variant.batch, entry.rung, "non-tail chunks run the tuned rung");
        } else if entry.variant.batch < entry.rung {
            tails_downshifted += 1;
        }
    }
    assert!(tails_downshifted > 0, "no tail chunk exercised the downshift");
}

#[test]
fn g_is_bitwise_identical_across_ladder_modes_pipelines_and_threads() {
    // the ladder A/B guarantee: merge units are carved along block
    // boundaries and per-quad evaluation is independent of its chunk, so
    // fixed and elastic ladders — despite chunking the work completely
    // differently — produce the same G, bit for bit, under either
    // pipeline and any thread count
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let build = |ladder: LadderMode, pipeline: PipelineMode, threads: usize| {
        let config = MatryoshkaConfig { ladder, pipeline, threads, ..Default::default() };
        engine("water", "6-31g*", config).two_electron(&d).unwrap()
    };
    let reference = build(LadderMode::Elastic, PipelineMode::Staged, 1);
    for (ladder, pipeline, threads) in [
        (LadderMode::Elastic, PipelineMode::Staged, 4),
        (LadderMode::Elastic, PipelineMode::Lockstep, 1),
        (LadderMode::Fixed, PipelineMode::Staged, 4),
        (LadderMode::Fixed, PipelineMode::Lockstep, 2),
        (LadderMode::Fixed, PipelineMode::Staged, 1),
    ] {
        let g = build(ladder, pipeline, threads);
        assert_eq!(
            reference.data(),
            g.data(),
            "{} ladder / {} pipeline / {threads} threads diverged",
            ladder.name(),
            pipeline.name()
        );
    }
}

#[test]
fn scf_energy_is_identical_across_ladder_modes() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();
    let run = |ladder: LadderMode| {
        let config = MatryoshkaConfig { ladder, ..Default::default() };
        let mut e = engine("water", "6-31g*", config);
        let res = run_rhf(&mol, &basis, &mut e, &opts).unwrap();
        assert!(res.converged);
        res.energy
    };
    let e_elastic = run(LadderMode::Elastic);
    let e_fixed = run(LadderMode::Fixed);
    // every Fock build is bitwise ladder-invariant, so the whole SCF
    // trajectory is too — exact equality, far inside the 1e-8 window
    assert_eq!(e_elastic, e_fixed, "{e_elastic} vs {e_fixed}");
}

#[test]
fn staged_metrics_attribute_stage_shapes_rungs_and_prefetch() {
    // 6-31G* mixes memory-bound s chunks (wide) with compute-bound d
    // chunks (split); a staged multi-unit build must attribute both,
    // record per-rung stats, and account cross-unit prefetch gathers
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let config = MatryoshkaConfig { threads: 2, ..Default::default() };
    let mut e = engine("water", "6-31g*", config);
    let schedule = e.build_schedule().unwrap();
    assert!(schedule.units.len() > 1, "need unit boundaries to prefetch across");
    e.two_electron(&d).unwrap();
    let m = &e.metrics;
    assert!(m.wide_chunks > 0, "s chunks should stage wide");
    assert!(m.split_chunks > 0, "d chunks should stage split");
    assert_eq!(m.wide_chunks + m.split_chunks, schedule.entries.len() as u64);
    assert!(!m.per_rung.is_empty());
    let rung_quads: u64 = m.per_rung.values().map(|s| s.real_quads).sum();
    assert_eq!(rung_quads, m.total_real_quads(), "rung attribution must cover every quad");
    assert!(
        m.prefetch_gather_seconds >= 0.0 && m.prefetch_gather_seconds <= m.gather_seconds,
        "prefetch time is a subset of gather time"
    );

    // lockstep never prefetches across units (the shape counters still
    // tally — they are schedule properties, not executor decisions)
    let lockstep = MatryoshkaConfig {
        pipeline: PipelineMode::Lockstep,
        threads: 2,
        ..Default::default()
    };
    let mut l = engine("water", "6-31g*", lockstep);
    l.two_electron(&d).unwrap();
    assert_eq!(l.metrics.prefetch_gather_seconds, 0.0);
}

/// Cache footprint (bytes) of a full stored-mode schedule for water —
/// the baseline the partial-budget tests slice.
fn water_cache_bytes() -> usize {
    let config = MatryoshkaConfig {
        stored: true,
        stored_budget_bytes: usize::MAX / 2,
        ..Default::default()
    };
    let probe = engine("water", "sto-3g", config);
    let schedule = probe.build_schedule().unwrap();
    schedule.entries.iter().map(|e| e.value_bytes()).sum()
}

#[test]
fn tiny_stored_budget_still_converges_to_the_same_scf_energy() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let opts = ScfOptions::default();

    let run = |stored: bool, budget: usize| {
        let config = MatryoshkaConfig {
            stored,
            stored_budget_bytes: budget,
            ..Default::default()
        };
        let mut e = engine("water", "sto-3g", config);
        let res = run_rhf(&mol, &basis, &mut e, &opts).unwrap();
        assert!(res.converged);
        (res.energy, e.cache_occupancy())
    };

    let full_bytes = water_cache_bytes();
    assert!(full_bytes > 0);

    let (e_direct, _) = run(false, 0);
    let (e_full, (full_cached, full_total)) = run(true, full_bytes);
    assert_eq!(full_cached, full_total, "exact-footprint budget caches every entry");
    assert!(full_total > 0);

    // a budget too small for even one entry: everything recomputes
    let (e_zero, (zero_cached, _)) = run(true, 1);
    assert_eq!(zero_cached, 0, "1-byte budget must cache nothing");

    // a mid-size budget: partial cache, tail recomputes each iteration
    let (e_tiny, (tiny_cached, tiny_total)) = run(true, full_bytes / 2);
    assert!(
        tiny_cached < tiny_total,
        "half-footprint budget should truncate the cache ({tiny_cached}/{tiny_total})"
    );

    // the three stored runs execute the identical frozen schedule, so
    // their trajectories are bitwise-identical: exact equality
    assert_eq!(e_full, e_zero, "budget changes memory use, never the result");
    assert_eq!(e_full, e_tiny, "budget changes memory use, never the result");
    // vs direct mode (schedule rebuilt per iteration) the trajectories
    // differ in rounding only — golden-test tolerance
    assert!(
        (e_full - e_direct).abs() < 1e-8,
        "stored energy {e_full} vs direct {e_direct}"
    );
}

#[test]
fn stored_partial_cache_g_is_bitwise_identical_to_direct_g() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut direct = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_direct = direct.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        stored: true,
        stored_budget_bytes: water_cache_bytes() / 2,
        ..Default::default()
    };
    let mut stored = engine("water", "sto-3g", config);
    let g_build = stored.two_electron(&d).unwrap(); // caching build
    let g_mixed = stored.two_electron(&d).unwrap(); // cached + recomputed mix
    let (cached, total) = stored.cache_occupancy();
    assert!(cached > 0 && cached < total, "want a genuine partial cache ({cached}/{total})");
    assert_eq!(g_direct.data(), g_build.data());
    assert_eq!(g_direct.data(), g_mixed.data());
}

/// Backend that works like native until `boom_after` executions, then
/// panics — the stand-in for a backend bug inside the compute stage.
struct PanickingBackend {
    inner: NativeBackend,
    boom_after: std::sync::atomic::AtomicUsize,
}

impl EriBackend for PanickingBackend {
    fn name(&self) -> &'static str {
        "panicking"
    }

    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn execute_eri(
        &self,
        variant: &Variant,
        bra_prim: &[f64],
        bra_geom: &[f64],
        ket_prim: &[f64],
        ket_geom: &[f64],
    ) -> anyhow::Result<EriExecution> {
        if self
            .boom_after
            .fetch_update(
                std::sync::atomic::Ordering::SeqCst,
                std::sync::atomic::Ordering::SeqCst,
                |n| n.checked_sub(1),
            )
            .is_err()
        {
            panic!("injected backend bug: kaboom");
        }
        self.inner.execute_eri(variant, bra_prim, bra_geom, ket_prim, ket_geom)
    }

    fn stats(&self) -> RuntimeStats {
        self.inner.stats()
    }
}

#[test]
fn worker_panic_propagates_its_payload_not_a_generic_error() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    for (mode, boom_after) in [
        (PipelineMode::Staged, 0),
        (PipelineMode::Lockstep, 0),
        // mid-build panic: some executions succeed first
        (PipelineMode::Staged, 3),
    ] {
        let backend = PanickingBackend {
            inner: NativeBackend::with_kpair(basis.max_kpair()),
            boom_after: std::sync::atomic::AtomicUsize::new(boom_after),
        };
        let config = MatryoshkaConfig { pipeline: mode, threads: 3, ..Default::default() };
        let mut engine =
            MatryoshkaEngine::with_backend(basis.clone(), Box::new(backend), config).unwrap();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.two_electron(&d)
        }));
        let payload = outcome.expect_err("backend panic must propagate, not vanish");
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(
            msg.contains("injected backend bug"),
            "{} mode surfaced the wrong payload: {msg:?}",
            mode.name()
        );
    }
}
