//! ERI-strategy acceptance tests (graph-compiled kernels).
//!
//! * Cross-strategy parity: the generated kernels and the memoized-tables
//!   interpreter are different factorizations of the same McMurchie–
//!   Davidson sum, so their G matrices agree to tight tolerance (never
//!   bitwise — the operation orders differ by construction).
//! * Within-strategy bitwise invariance: for a fixed strategy, G must not
//!   change a single bit across thread count, batch ladder, pipeline mode
//!   or `--dispatch local:2` — chunk boundaries and execution interleaving
//!   are not allowed to touch the floating-point result.
//! * Golden SCF: the kernels strategy reproduces the tables-strategy SCF
//!   energy on 6-31G* water (d classes exercised end to end).

use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::dispatch::{DispatchConfig, DispatchMode};
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::{EriEvalStrategy, LadderMode};
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn build_g(config: MatryoshkaConfig) -> Matrix {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let mut engine = MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap();
    engine.two_electron(&d).unwrap()
}

#[test]
fn kernels_g_matches_tables_oracle_on_631gstar_water() {
    let kernels = build_g(MatryoshkaConfig {
        eri_strategy: EriEvalStrategy::Kernels,
        ..Default::default()
    });
    let tables = build_g(MatryoshkaConfig {
        eri_strategy: EriEvalStrategy::Tables,
        ..Default::default()
    });
    let diff = kernels.diff_norm(&tables);
    assert!(diff < 1e-8, "||G_kernels − G_tables|| = {diff:.3e}");
}

#[test]
fn g_is_bitwise_invariant_within_each_strategy() {
    for strategy in [EriEvalStrategy::Kernels, EriEvalStrategy::Tables] {
        let base = MatryoshkaConfig { eri_strategy: strategy, threads: 1, ..Default::default() };
        let g_ref = build_g(base.clone());

        // thread count, batch ladder and pipeline mode only move chunk
        // boundaries and interleaving — per-quad values and the digestion
        // order are invariants, so G must be bit-identical
        let variations: Vec<(&str, MatryoshkaConfig)> = vec![
            ("3 threads", MatryoshkaConfig { threads: 3, ..base.clone() }),
            ("fixed ladder", MatryoshkaConfig { ladder: LadderMode::Fixed, ..base.clone() }),
            (
                "fixed ladder, 3 threads",
                MatryoshkaConfig { ladder: LadderMode::Fixed, threads: 3, ..base.clone() },
            ),
            (
                "lockstep pipeline",
                MatryoshkaConfig { pipeline: PipelineMode::Lockstep, ..base.clone() },
            ),
        ];
        for (what, config) in variations {
            let g = build_g(config);
            assert_eq!(
                g_ref.data(),
                g.data(),
                "{} / {what}: G diverged bitwise",
                strategy.name()
            );
        }
    }
}

#[test]
fn dispatched_g_is_bitwise_identical_per_strategy() {
    for strategy in [EriEvalStrategy::Kernels, EriEvalStrategy::Tables] {
        let g_ref = build_g(MatryoshkaConfig { eri_strategy: strategy, ..Default::default() });
        let dispatched = build_g(MatryoshkaConfig {
            eri_strategy: strategy,
            dispatch: DispatchConfig {
                mode: DispatchMode::Local(2),
                worker_bin: Some(worker_bin()),
                ..Default::default()
            },
            ..Default::default()
        });
        assert_eq!(
            g_ref.data(),
            dispatched.data(),
            "{}: local:2 G diverged from the in-process build",
            strategy.name()
        );
    }
}

#[test]
fn kernels_scf_energy_matches_tables_on_631gstar_water() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();

    let run = |strategy: EriEvalStrategy| {
        let config = MatryoshkaConfig { eri_strategy: strategy, ..Default::default() };
        let mut engine =
            MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
        run_rhf(&mol, &basis, &mut engine, &opts).unwrap()
    };
    let kernels = run(EriEvalStrategy::Kernels);
    let tables = run(EriEvalStrategy::Tables);
    assert!(kernels.converged && tables.converged);
    assert!(
        (kernels.energy - tables.energy).abs() < 1e-9,
        "kernels {} vs tables {}",
        kernels.energy,
        tables.energy
    );
    // literature RHF/6-31G* water ≈ −76.01 Ha
    assert!((kernels.energy + 76.01).abs() < 0.01, "water/6-31g* E = {:.7}", kernels.energy);
}
