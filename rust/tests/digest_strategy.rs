//! Digestion-strategy acceptance tests (tiled block-GEMM contraction).
//!
//! * Cross-strategy parity: the block GEMM and the per-quad 8-image
//!   scatter are different associations of the same contraction, so
//!   their G matrices agree to tight tolerance (never bitwise — the
//!   floating-point summation orders differ by construction).  The
//!   scatter path is the permanent parity oracle.
//! * Within-strategy bitwise invariance: for a fixed digestion strategy,
//!   G must not change a single bit across thread count, batch ladder,
//!   pipeline mode or `--dispatch local:2` — digestion runs on the
//!   memory stage in strict schedule-entry order either way.
//! * Golden SCF: the GEMM digestion reproduces the scatter SCF energy on
//!   6-31G* water and methane (d classes and every shell-coincidence
//!   mask exercised end to end).

use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::dispatch::{DispatchConfig, DispatchMode};
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::fock::DigestStrategy;
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::LadderMode;
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn build_g(molecule: &str, config: MatryoshkaConfig) -> Matrix {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let mut engine = MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap();
    engine.two_electron(&d).unwrap()
}

#[test]
fn gemm_g_matches_scatter_oracle_on_631gstar_systems() {
    for molecule in ["water", "methane"] {
        let gemm = build_g(
            molecule,
            MatryoshkaConfig { digest: DigestStrategy::Gemm, ..Default::default() },
        );
        let scatter = build_g(
            molecule,
            MatryoshkaConfig { digest: DigestStrategy::Scatter, ..Default::default() },
        );
        let diff = gemm.diff_norm(&scatter);
        assert!(diff < 1e-10, "{molecule}: ||G_gemm − G_scatter|| = {diff:.3e}");
    }
}

#[test]
fn g_is_bitwise_invariant_within_each_digest_strategy() {
    for digest in [DigestStrategy::Gemm, DigestStrategy::Scatter] {
        let base = MatryoshkaConfig { digest, threads: 1, ..Default::default() };
        let g_ref = build_g("water", base.clone());

        // thread count, batch ladder and pipeline mode only move chunk
        // boundaries and interleaving — per-quad values and the
        // schedule-entry digestion order are invariants, so G must be
        // bit-identical within one digestion strategy
        let variations: Vec<(&str, MatryoshkaConfig)> = vec![
            ("3 threads", MatryoshkaConfig { threads: 3, ..base.clone() }),
            ("fixed ladder", MatryoshkaConfig { ladder: LadderMode::Fixed, ..base.clone() }),
            (
                "fixed ladder, 3 threads",
                MatryoshkaConfig { ladder: LadderMode::Fixed, threads: 3, ..base.clone() },
            ),
            (
                "lockstep pipeline",
                MatryoshkaConfig { pipeline: PipelineMode::Lockstep, ..base.clone() },
            ),
        ];
        for (what, config) in variations {
            let g = build_g("water", config);
            assert_eq!(
                g_ref.data(),
                g.data(),
                "{} / {what}: G diverged bitwise",
                digest.name()
            );
        }
    }
}

#[test]
fn dispatched_g_is_bitwise_identical_per_digest_strategy() {
    for digest in [DigestStrategy::Gemm, DigestStrategy::Scatter] {
        let g_ref = build_g("water", MatryoshkaConfig { digest, ..Default::default() });
        let dispatched = build_g(
            "water",
            MatryoshkaConfig {
                digest,
                dispatch: DispatchConfig {
                    mode: DispatchMode::Local(2),
                    worker_bin: Some(worker_bin()),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        assert_eq!(
            g_ref.data(),
            dispatched.data(),
            "{}: local:2 G diverged from the in-process build",
            digest.name()
        );
    }
}

#[test]
fn gemm_scf_energy_matches_scatter_on_631gstar_systems() {
    for (molecule, literature) in [("water", -76.01), ("methane", -40.19)] {
        let mol = library::by_name(molecule).unwrap();
        let basis = build_basis(&mol, "6-31g*").unwrap();
        let opts = ScfOptions::default();

        let run = |digest: DigestStrategy| {
            let config = MatryoshkaConfig { digest, ..Default::default() };
            let mut engine =
                MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
            run_rhf(&mol, &basis, &mut engine, &opts).unwrap()
        };
        let gemm = run(DigestStrategy::Gemm);
        let scatter = run(DigestStrategy::Scatter);
        assert!(gemm.converged && scatter.converged);
        assert!(
            (gemm.energy - scatter.energy).abs() < 1e-9,
            "{molecule}: gemm {} vs scatter {}",
            gemm.energy,
            scatter.energy
        );
        assert!(
            (gemm.energy - literature).abs() < 0.01,
            "{molecule}/6-31g* E = {:.7}",
            gemm.energy
        );
    }
}

#[test]
fn gemm_digest_seconds_are_attributed_per_strategy() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    for digest in [DigestStrategy::Gemm, DigestStrategy::Scatter] {
        let config = MatryoshkaConfig { digest, ..Default::default() };
        let mut engine =
            MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
        engine.two_electron(&d).unwrap();
        let m = &engine.metrics;
        assert_eq!(
            m.per_digest.keys().collect::<Vec<_>>(),
            vec![digest.name()],
            "digest seconds must be attributed to the strategy that ran"
        );
        let attributed: f64 = m.per_digest.values().sum();
        assert!(attributed > 0.0, "{}: no digest time recorded", digest.name());
        assert!(
            (attributed - m.digest_seconds).abs() <= 1e-9 * m.digest_seconds.max(1.0),
            "{}: per-strategy digest time {attributed} disagrees with the total {}",
            digest.name(),
            m.digest_seconds
        );
    }
}
