//! Distributed-dispatch acceptance tests.
//!
//! The acceptance bar (ISSUE 5, extended by ISSUE 9): G and the final
//! SCF energy must be **bitwise identical** across in-process,
//! `--dispatch local:1` and `--dispatch local:2` builds; the unit-order
//! merge must survive work-stealing rebalance; and — the fault-tolerance
//! bar — a worker killed mid-build, a corrupt frame, a dropped TCP
//! connection, or the death of the ENTIRE fleet must all still complete
//! the build with the same bitwise G (survivors and the in-process
//! fallback run the identical unit code path).  A schedule-fingerprint
//! or shared-secret mismatch must be rejected before any unit executes.
//!
//! Local workers are real subprocesses of the `matryoshka` binary
//! (`CARGO_BIN_EXE_matryoshka` — the test harness binary itself has no
//! `worker` subcommand).  Remote mode is exercised over loopback TCP
//! with in-thread workers running the same `dispatch::worker::serve`.
//! Chaos is injected with the same `--inject` specs the CLI exposes, so
//! every failure here is deterministic and reproducible by hand.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use matryoshka::basis::build_basis;
use matryoshka::constructor::SchwarzMode;
use matryoshka::dispatch::proto::{auth_tag, read_msg, write_msg};
use matryoshka::dispatch::worker::{serve, InjectKind, InjectSpec, WorkerOptions};
use matryoshka::dispatch::{DispatchConfig, DispatchMode, JobSpec, Msg, PROTO_VERSION};
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::{BackendKind, LadderMode};
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn engine(molecule: &str, basis_name: &str, config: MatryoshkaConfig) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

fn local_dispatch(n: usize) -> DispatchConfig {
    DispatchConfig {
        mode: DispatchMode::Local(n),
        worker_bin: Some(worker_bin()),
        ..Default::default()
    }
}

/// Spawn an in-thread TCP worker that serves exactly one session.
fn one_shot_worker(
    opts: WorkerOptions,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || -> anyhow::Result<()> {
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true).ok();
        let mut r = BufReader::new(stream.try_clone()?);
        let mut w = BufWriter::new(stream);
        serve(&mut r, &mut w, &opts)
    });
    (addr, handle)
}

/// Spawn an in-thread TCP worker that keeps accepting new sessions —
/// the `worker --listen` loop the rejoin path needs.  Detached: the
/// thread dies with the test process.
fn rejoinable_worker(listener: TcpListener, opts: WorkerOptions) {
    std::thread::spawn(move || {
        loop {
            let Ok((stream, _)) = listener.accept() else { return };
            stream.set_nodelay(true).ok();
            let Ok(clone) = stream.try_clone() else { return };
            let mut r = BufReader::new(clone);
            let mut w = BufWriter::new(stream);
            match serve(&mut r, &mut w, &opts) {
                Ok(()) => {}
                Err(e) => eprintln!("test worker session ended: {e}"),
            }
        }
    });
}

#[test]
fn dispatched_g_is_bitwise_identical_to_in_process_on_631gstar_water() {
    // 6-31G* water lights up the d classes, multiple merge units, and
    // both stage shapes — the full execution surface crosses the wire
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let mut in_process = engine("water", "6-31g*", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    for workers in [1usize, 2] {
        let config = MatryoshkaConfig { dispatch: local_dispatch(workers), ..Default::default() };
        let mut e = engine("water", "6-31g*", config);
        let g = e.two_electron(&d).unwrap();
        assert_eq!(
            g_ref.data(),
            g.data(),
            "local:{workers} G diverged from the in-process build"
        );
        // a second build reuses the same workers (no respawn) and must
        // stay bitwise identical too
        let g2 = e.two_electron(&d).unwrap();
        assert_eq!(g_ref.data(), g2.data(), "local:{workers} second build diverged");
        let stats = e.dispatch_stats().expect("dispatched builds ran");
        assert_eq!(stats.len(), workers);
        let units: u64 = stats.iter().map(|s| s.units).sum();
        let schedule = e.build_schedule().unwrap();
        assert_eq!(units, 2 * schedule.units.len() as u64, "every unit attributed, twice");
        if workers == 2 {
            assert!(
                stats.iter().all(|s| s.units > 0),
                "both workers should have contributed: {stats:?}"
            );
        }
        assert!(stats.iter().all(|s| s.lost == 0), "no faults on the happy path: {stats:?}");
    }
}

#[test]
fn dispatched_scf_energy_is_exactly_the_in_process_energy() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();

    let mut reference = engine("water", "6-31g*", MatryoshkaConfig::default());
    let res_ref = run_rhf(&mol, &basis, &mut reference, &opts).unwrap();
    assert!(res_ref.converged);

    let config = MatryoshkaConfig { dispatch: local_dispatch(2), ..Default::default() };
    let mut dispatched = engine("water", "6-31g*", config);
    let res = run_rhf(&mol, &basis, &mut dispatched, &opts).unwrap();
    assert!(res.converged);

    // every Fock build is bitwise identical, so the whole SCF trajectory
    // is too: exact equality, not a tolerance
    assert_eq!(res.energy, res_ref.energy, "dispatched SCF drifted");
    assert_eq!(res.iterations, res_ref.iterations);
    assert_eq!(res.energy_trace, res_ref.energy_trace);
}

#[test]
fn remote_tcp_dispatch_matches_in_process_bitwise() {
    // in-thread TCP workers: same serve loop the `worker --listen` CLI
    // runs, dialed through DispatchMode::Remote
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for index in 0..2usize {
        let (addr, handle) = one_shot_worker(WorkerOptions { index, ..Default::default() });
        addrs.push(addr);
        handles.push(handle);
    }

    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(addrs),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "remote TCP G diverged");
    drop(e); // sends Shutdown; workers exit their serve loops cleanly
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn work_stealing_rebalance_preserves_the_unit_order_merge_bitwise() {
    // worker 0 stalls 2.5s before delivering its first shard; with a
    // 200ms straggler timeout the dispatcher must rebalance worker 0's
    // outstanding units onto worker 1 and still produce the identical G
    // (first shard per unit wins; both are bitwise the same anyway)
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 200,
            worker_args: vec!["--test-stall".into(), "0:0:2500".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "rebalanced G diverged from the in-process build");
    let stats = e.dispatch_stats().expect("dispatched build ran");
    assert!(
        stats.iter().any(|s| s.rebalanced_away > 0),
        "the stalled worker's units were never rebalanced: {stats:?}"
    );
    // the healthy worker must have carried (at least) the stolen units
    assert!(stats.iter().any(|s| s.units > 0 && s.rebalanced_away == 0), "{stats:?}");
}

#[test]
fn killing_one_of_three_workers_mid_build_keeps_g_bitwise() {
    // the ISSUE 9 acceptance case: `--dispatch local:3` with worker 1
    // crashing after its first shard (dirty death, no Error frame).  The
    // coordinator must requeue its outstanding units onto the survivors
    // and the merged G must stay bitwise identical.
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "6-31g*", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(3),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 500,
            worker_args: vec!["--inject".into(), "kill-after:1@1".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "6-31g*", config);
    let started = std::time::Instant::now();
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "G diverged after a mid-build worker crash");
    assert!(
        started.elapsed() < Duration::from_secs(120),
        "recovery took {:?} — that is a hang, not fault tolerance",
        started.elapsed()
    );
    let stats = e.dispatch_stats().expect("dispatched build ran");
    let lost: u64 = stats.iter().map(|s| s.lost).sum();
    assert_eq!(lost, 1, "exactly one worker died: {stats:?}");
    let dead = stats.iter().find(|s| s.lost == 1).unwrap();
    assert_eq!(dead.label, "local:1", "{stats:?}");
    // every unit still attributed exactly once across survivors
    let units: u64 = stats.iter().map(|s| s.units).sum();
    let schedule = e.build_schedule().unwrap();
    assert_eq!(units, schedule.units.len() as u64, "{stats:?}");
}

#[test]
fn whole_fleet_death_falls_back_in_process_and_stays_bitwise() {
    // every worker crashes after its first shard.  Builds must still
    // COMPLETE: survivors absorb requeued units until nobody is left,
    // then the engine executes the missing units in-process through the
    // same run_units_streamed path — bitwise-identical G, never an error.
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 500,
            worker_args: vec!["--inject".into(), "kill-after:1".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let started = std::time::Instant::now();
    // a worker only dies after it delivers a shard, so keep building
    // until both have been drawn in and killed (first build usually
    // does it; a straggling second worker dies on the next build when
    // it becomes the sole target)
    let mut lost = 0u64;
    for build in 0..4 {
        let g = e.two_electron(&d).unwrap();
        assert_eq!(g_ref.data(), g.data(), "build {build} diverged during fleet collapse");
        lost = e.dispatch_stats().unwrap().iter().map(|s| s.lost).sum();
        if lost == 2 {
            break;
        }
    }
    assert_eq!(lost, 2, "both workers should have died: {:?}", e.dispatch_stats());
    assert!(
        started.elapsed() < Duration::from_secs(120),
        "fleet-death recovery took {:?} — that is a hang",
        started.elapsed()
    );
    // with the fleet exhausted the engine skips the wire entirely and
    // still produces the identical G fully in-process
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "post-collapse in-process build diverged");
    let summary = e.dispatch_summary().unwrap();
    assert!(summary.contains("faults:"), "{summary}");
}

#[test]
fn corrupt_frame_loses_only_the_sending_worker() {
    // worker 0 sends one good shard, then a garbage frame, then dies.
    // The coordinator's decoder must reject the frame (never panic or
    // misparse), write worker 0 off, and finish bitwise on worker 1.
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 500,
            worker_args: vec!["--inject".into(), "corrupt-frame:1@0".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "G diverged after a corrupt frame");
    let stats = e.dispatch_stats().expect("dispatched build ran");
    let lost: u64 = stats.iter().map(|s| s.lost).sum();
    assert_eq!(lost, 1, "only the corrupting worker dies: {stats:?}");
    assert_eq!(stats.iter().find(|s| s.lost == 1).unwrap().label, "local:0", "{stats:?}");
}

#[test]
fn dropped_tcp_worker_rejoins_as_a_new_member_bitwise() {
    // worker 0 cleanly drops its connection after every first shard but
    // keeps listening; worker 1 is healthy.  The coordinator must park
    // the dropped address, re-dial it with backoff, and admit the fresh
    // session mid-SCF through the full handshake — elastic membership.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr0 = listener.local_addr().unwrap().to_string();
    rejoinable_worker(
        listener,
        WorkerOptions {
            index: 0,
            inject: Some(InjectSpec { kind: InjectKind::DropConn(1), only_worker: None }),
            ..Default::default()
        },
    );
    let (addr1, healthy) = one_shot_worker(WorkerOptions { index: 1, ..Default::default() });

    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(vec![addr0, addr1]),
            straggler_timeout_ms: 300,
            dial_backoff_ms: 50,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let mut rejoined = false;
    for build in 0..10 {
        let g = e.two_electron(&d).unwrap();
        assert_eq!(g_ref.data(), g.data(), "build {build} diverged across a connection drop");
        let stats = e.dispatch_stats().unwrap();
        if stats.iter().any(|s| s.lost == 1) && stats.iter().any(|s| s.joined_mid_scf == 1) {
            rejoined = true;
            break;
        }
        // give the parked address's backoff a chance to expire
        std::thread::sleep(Duration::from_millis(60));
    }
    assert!(
        rejoined,
        "the dropped worker never rejoined: {:?}",
        e.dispatch_stats()
    );
    drop(e);
    healthy.join().unwrap().unwrap();
}

#[test]
fn late_starting_worker_joins_mid_scf_bitwise() {
    // addr0's worker is not even listening at launch: the coordinator
    // must park it (launch succeeds on the one reachable worker) and
    // keep re-dialing until the late worker appears, then admit it with
    // the Setup + current-Build replay — without disturbing bitwise G.
    let probe = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr0 = probe.local_addr().unwrap().to_string();
    drop(probe); // free the port; the worker binds it 300ms from now
    {
        let addr0 = addr0.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            let listener = match TcpListener::bind(&addr0) {
                Ok(l) => l,
                Err(e) => {
                    eprintln!("late worker could not rebind {addr0}: {e}");
                    return;
                }
            };
            rejoinable_worker(listener, WorkerOptions { index: 0, ..Default::default() });
        });
    }
    let (addr1, healthy) = one_shot_worker(WorkerOptions { index: 1, ..Default::default() });

    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(vec![addr0, addr1]),
            straggler_timeout_ms: 300,
            dial_retries: 2,
            dial_backoff_ms: 50,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let mut joined = false;
    for build in 0..60 {
        let g = e.two_electron(&d).unwrap();
        assert_eq!(g_ref.data(), g.data(), "build {build} diverged around the late join");
        if e.dispatch_stats().unwrap().iter().any(|s| s.joined_mid_scf == 1) {
            joined = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(joined, "the late worker never joined: {:?}", e.dispatch_stats());
    drop(e);
    healthy.join().unwrap().unwrap();
}

#[test]
fn scf_survives_a_collapsing_fleet_with_the_exact_reference_energy() {
    // full SCF under maximum chaos: every worker crashes after its first
    // shard, so the fleet collapses over the first builds and the rest
    // of the SCF runs through the in-process fallback.  The trajectory
    // must be EXACTLY the undisturbed one — same energy, same iteration
    // count, same per-iteration trace.
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let opts = ScfOptions::default();

    let mut reference = engine("water", "sto-3g", MatryoshkaConfig::default());
    let res_ref = run_rhf(&mol, &basis, &mut reference, &opts).unwrap();
    assert!(res_ref.converged);

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(3),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 500,
            worker_args: vec!["--inject".into(), "kill-after:1".into()],
            ..Default::default()
        },
        ..Default::default()
    };
    let mut chaotic = engine("water", "sto-3g", config);
    let res = run_rhf(&mol, &basis, &mut chaotic, &opts).unwrap();
    assert!(res.converged);
    assert_eq!(res.energy, res_ref.energy, "chaos SCF drifted from the reference");
    assert_eq!(res.iterations, res_ref.iterations);
    assert_eq!(res.energy_trace, res_ref.energy_trace);
    let stats = chaotic.dispatch_stats().expect("dispatched builds ran");
    let lost: u64 = stats.iter().map(|s| s.lost).sum();
    assert!(lost >= 1, "at least one injected crash must have fired: {stats:?}");
}

#[test]
fn wrong_dispatch_secret_is_refused_before_any_work() {
    // the worker holds "s3cret", the coordinator dials with "wrong": the
    // worker must refuse the Setup auth tag with a FATAL error (launch
    // aborts — a misconfigured fleet is not a runtime fault) and no
    // build may start
    let (addr, worker) = one_shot_worker(WorkerOptions {
        index: 0,
        secret: "s3cret".into(),
        ..Default::default()
    });

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(vec![addr]),
            secret: Some("wrong".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut e = engine("water", "sto-3g", config);
    let err = e.two_electron(&d).unwrap_err().to_string();
    assert!(err.contains("secret mismatch"), "launch must name the secret mismatch: {err}");
    let worker_err = worker.join().unwrap().unwrap_err().to_string();
    assert!(worker_err.contains("secret mismatch"), "{worker_err}");
}

#[test]
fn matching_dispatch_secret_authenticates_and_stays_bitwise() {
    // both ends hold the same secret: handshake succeeds, G is bitwise
    let (addr, worker) = one_shot_worker(WorkerOptions {
        index: 0,
        secret: "s3cret".into(),
        ..Default::default()
    });
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(vec![addr]),
            secret: Some("s3cret".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "authenticated dispatch diverged");
    drop(e);
    worker.join().unwrap().unwrap();
}

#[test]
fn schedule_fingerprint_mismatch_is_rejected_before_any_execution() {
    // drive a real worker through the v5 protocol by hand and hand it a
    // Build whose fingerprint cannot match: the worker must refuse with
    // a FATAL Error frame (and die with the same message), never execute
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let nbf = basis.nbf;
    let spec = JobSpec {
        title: "fingerprint mismatch test".into(),
        basis,
        threshold: 1e-10,
        tile: 64,
        clustered: true,
        greedy_path: true,
        fixed_batch: 512,
        schwarz: SchwarzMode::Exact,
        backend: BackendKind::Native,
        ladder: LadderMode::Elastic,
        eri_strategy: Default::default(),
        digest: Default::default(),
        working_set_bytes: 4 << 20,
        wide_opb_max: 4.0,
        threads: 1,
        pipeline: PipelineMode::Staged,
        artifact_dir: "unused".into(),
        schwarz_cal_path: None,
        trace: false,
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker = std::thread::spawn(move || -> anyhow::Result<()> {
        let (stream, _) = listener.accept()?;
        let mut r = BufReader::new(stream.try_clone()?);
        let mut w = BufWriter::new(stream);
        serve(&mut r, &mut w, &WorkerOptions::default())
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = BufWriter::new(stream);
    let hello_nonce = match read_msg(&mut r).unwrap() {
        Msg::Hello { version, nonce } => {
            assert_eq!(version, PROTO_VERSION);
            nonce
        }
        other => panic!("expected Hello, got {}", other.kind()),
    };
    // answer the worker's secret challenge (both ends secretless here)
    // and issue our own
    write_msg(
        &mut w,
        &Msg::Setup { spec: Box::new(spec), nonce: 7, auth: auth_tag("", hello_nonce) },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::SetupAck { nbf: got, auth, .. } => {
            assert_eq!(got, nbf);
            assert_eq!(auth, auth_tag("", 7), "worker must answer the coordinator's challenge");
        }
        other => panic!("expected SetupAck, got {}", other.kind()),
    }
    write_msg(
        &mut w,
        &Msg::Build {
            iter: 1,
            fingerprint: 0xdead_beef,
            delta_screen: false,
            snapshot: BTreeMap::new(),
            density: Matrix::zeros(nbf, nbf),
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { fatal, message } => {
            assert!(fatal, "fingerprint drift must be fatal, not a recoverable loss");
            assert!(message.contains("fingerprint mismatch"), "{message}");
            assert!(message.contains("refusing to execute"), "{message}");
        }
        other => panic!("expected Error, got {}", other.kind()),
    }
    let err = worker.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("fingerprint mismatch"), "{err}");
}

#[test]
fn report_dispatch_table_attributes_every_worker() {
    let table =
        matryoshka::report::dispatch_table("water", "sto-3g", 2, Some(worker_bin())).unwrap();
    assert!(table.contains("Dispatch attribution"), "{table}");
    assert!(table.contains("local:0"), "{table}");
    assert!(table.contains("local:1"), "{table}");
    assert!(table.contains("2 Fock build(s)"), "{table}");
    assert!(table.contains("flop balance"), "{table}");
}

#[test]
fn dispatched_build_with_persisted_schwarz_calibration_stays_bitwise() {
    // the coordinator calibrates + writes the table; the spec carries the
    // path, so every worker loads it instead of recalibrating — and the
    // corrected Estimate screening stays bitwise identical end to end
    let cal = std::env::temp_dir()
        .join(format!("matryoshka_dispatch_cal_{}.txt", std::process::id()));
    let _ = std::fs::remove_file(&cal);
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let base = MatryoshkaConfig { schwarz: SchwarzMode::Estimate, ..Default::default() };
    let mut in_process = engine("water", "6-31g*", base.clone());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        schwarz: SchwarzMode::Estimate,
        schwarz_cal_path: Some(cal.to_string_lossy().into_owned()),
        dispatch: local_dispatch(2),
        ..base
    };
    let mut e = engine("water", "6-31g*", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "persisted-calibration dispatch diverged");
    assert!(cal.exists(), "coordinator must have written the calibration table");
    let _ = std::fs::remove_file(&cal);
}
