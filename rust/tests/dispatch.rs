//! Distributed-dispatch acceptance tests.
//!
//! The acceptance bar (ISSUE 5): G and the final SCF energy must be
//! **bitwise identical** across in-process, `--dispatch local:1` and
//! `--dispatch local:2` builds; the unit-order merge must survive
//! work-stealing rebalance; a worker crash must surface as a dispatcher
//! error (never a hang); and a schedule-fingerprint mismatch must be
//! rejected before any unit executes.
//!
//! Local workers are real subprocesses of the `matryoshka` binary
//! (`CARGO_BIN_EXE_matryoshka` — the test harness binary itself has no
//! `worker` subcommand).  Remote mode is exercised over loopback TCP
//! with in-thread workers running the same `dispatch::worker::serve`.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::constructor::SchwarzMode;
use matryoshka::dispatch::proto::{read_msg, write_msg};
use matryoshka::dispatch::worker::{serve, WorkerOptions};
use matryoshka::dispatch::{DispatchConfig, DispatchMode, JobSpec, Msg, PROTO_VERSION};
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::PipelineMode;
use matryoshka::runtime::{BackendKind, LadderMode};
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn engine(molecule: &str, basis_name: &str, config: MatryoshkaConfig) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

fn local_dispatch(n: usize) -> DispatchConfig {
    DispatchConfig {
        mode: DispatchMode::Local(n),
        worker_bin: Some(worker_bin()),
        ..Default::default()
    }
}

#[test]
fn dispatched_g_is_bitwise_identical_to_in_process_on_631gstar_water() {
    // 6-31G* water lights up the d classes, multiple merge units, and
    // both stage shapes — the full execution surface crosses the wire
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let mut in_process = engine("water", "6-31g*", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    for workers in [1usize, 2] {
        let config = MatryoshkaConfig { dispatch: local_dispatch(workers), ..Default::default() };
        let mut e = engine("water", "6-31g*", config);
        let g = e.two_electron(&d).unwrap();
        assert_eq!(
            g_ref.data(),
            g.data(),
            "local:{workers} G diverged from the in-process build"
        );
        // a second build reuses the same workers (no respawn) and must
        // stay bitwise identical too
        let g2 = e.two_electron(&d).unwrap();
        assert_eq!(g_ref.data(), g2.data(), "local:{workers} second build diverged");
        let stats = e.dispatch_stats().expect("dispatched builds ran");
        assert_eq!(stats.len(), workers);
        let units: u64 = stats.iter().map(|s| s.units).sum();
        let schedule = e.build_schedule().unwrap();
        assert_eq!(units, 2 * schedule.units.len() as u64, "every unit attributed, twice");
        if workers == 2 {
            assert!(
                stats.iter().all(|s| s.units > 0),
                "both workers should have contributed: {stats:?}"
            );
        }
    }
}

#[test]
fn dispatched_scf_energy_is_exactly_the_in_process_energy() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();

    let mut reference = engine("water", "6-31g*", MatryoshkaConfig::default());
    let res_ref = run_rhf(&mol, &basis, &mut reference, &opts).unwrap();
    assert!(res_ref.converged);

    let config = MatryoshkaConfig { dispatch: local_dispatch(2), ..Default::default() };
    let mut dispatched = engine("water", "6-31g*", config);
    let res = run_rhf(&mol, &basis, &mut dispatched, &opts).unwrap();
    assert!(res.converged);

    // every Fock build is bitwise identical, so the whole SCF trajectory
    // is too: exact equality, not a tolerance
    assert_eq!(res.energy, res_ref.energy, "dispatched SCF drifted");
    assert_eq!(res.iterations, res_ref.iterations);
    assert_eq!(res.energy_trace, res_ref.energy_trace);
}

#[test]
fn remote_tcp_dispatch_matches_in_process_bitwise() {
    // in-thread TCP workers: same serve loop the `worker --listen` CLI
    // runs, dialed through DispatchMode::Remote
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for index in 0..2usize {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let (stream, _) = listener.accept()?;
            stream.set_nodelay(true).ok();
            let mut r = BufReader::new(stream.try_clone()?);
            let mut w = BufWriter::new(stream);
            serve(&mut r, &mut w, &WorkerOptions { index, ..Default::default() })
        }));
    }

    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Remote(addrs),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "remote TCP G diverged");
    drop(e); // sends Shutdown; workers exit their serve loops cleanly
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn work_stealing_rebalance_preserves_the_unit_order_merge_bitwise() {
    // worker 0 stalls 2.5s before delivering its first shard; with a
    // 200ms straggler timeout the dispatcher must rebalance worker 0's
    // outstanding units onto worker 1 and still produce the identical G
    // (first shard per unit wins; both are bitwise the same anyway)
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut in_process = engine("water", "sto-3g", MatryoshkaConfig::default());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 200,
            worker_args: vec!["--test-stall".into(), "0:0:2500".into()],
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "rebalanced G diverged from the in-process build");
    let stats = e.dispatch_stats().expect("dispatched build ran");
    assert!(
        stats.iter().any(|s| s.rebalanced_away > 0),
        "the stalled worker's units were never rebalanced: {stats:?}"
    );
    // the healthy worker must have carried (at least) the stolen units
    assert!(stats.iter().any(|s| s.units > 0 && s.rebalanced_away == 0), "{stats:?}");
}

#[test]
fn worker_crash_surfaces_as_a_dispatcher_error_not_a_hang() {
    // both workers drop their connection after one shard — the reader
    // threads see EOF and the build must fail fast with a real error
    let config = MatryoshkaConfig {
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            straggler_timeout_ms: 500,
            worker_args: vec!["--test-exit-after-shards".into(), "1".into()],
        },
        ..Default::default()
    };
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let mut e = engine("water", "sto-3g", config);
    let started = std::time::Instant::now();
    let err = e.two_electron(&d).unwrap_err().to_string();
    assert!(
        err.contains("disconnected"),
        "crash must surface as a disconnect error, got: {err}"
    );
    assert!(
        started.elapsed() < std::time::Duration::from_secs(60),
        "crash detection took {:?} — that is a hang, not an error path",
        started.elapsed()
    );
}

#[test]
fn schedule_fingerprint_mismatch_is_rejected_before_any_execution() {
    // drive a real worker through the protocol by hand and hand it a
    // Build whose fingerprint cannot match: the worker must refuse with
    // an Error frame (and die with the same message), never execute
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let nbf = basis.nbf;
    let spec = JobSpec {
        title: "fingerprint mismatch test".into(),
        basis,
        threshold: 1e-10,
        tile: 64,
        clustered: true,
        greedy_path: true,
        fixed_batch: 512,
        schwarz: SchwarzMode::Exact,
        backend: BackendKind::Native,
        ladder: LadderMode::Elastic,
        eri_strategy: Default::default(),
        digest: Default::default(),
        working_set_bytes: 4 << 20,
        wide_opb_max: 4.0,
        threads: 1,
        pipeline: PipelineMode::Staged,
        artifact_dir: "unused".into(),
        schwarz_cal_path: None,
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker = std::thread::spawn(move || -> anyhow::Result<()> {
        let (stream, _) = listener.accept()?;
        let mut r = BufReader::new(stream.try_clone()?);
        let mut w = BufWriter::new(stream);
        serve(&mut r, &mut w, &WorkerOptions::default())
    });

    let stream = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = BufWriter::new(stream);
    match read_msg(&mut r).unwrap() {
        Msg::Hello { version } => assert_eq!(version, PROTO_VERSION),
        other => panic!("expected Hello, got {}", other.kind()),
    }
    write_msg(&mut w, &Msg::Setup { spec: Box::new(spec) }).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::SetupAck { nbf: got, .. } => assert_eq!(got, nbf),
        other => panic!("expected SetupAck, got {}", other.kind()),
    }
    write_msg(
        &mut w,
        &Msg::Build {
            iter: 1,
            fingerprint: 0xdead_beef,
            delta_screen: false,
            snapshot: BTreeMap::new(),
            density: Matrix::zeros(nbf, nbf),
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("fingerprint mismatch"), "{message}");
            assert!(message.contains("refusing to execute"), "{message}");
        }
        other => panic!("expected Error, got {}", other.kind()),
    }
    let err = worker.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("fingerprint mismatch"), "{err}");
}

#[test]
fn report_dispatch_table_attributes_every_worker() {
    let table =
        matryoshka::report::dispatch_table("water", "sto-3g", 2, Some(worker_bin())).unwrap();
    assert!(table.contains("Dispatch attribution"), "{table}");
    assert!(table.contains("local:0"), "{table}");
    assert!(table.contains("local:1"), "{table}");
    assert!(table.contains("2 Fock build(s)"), "{table}");
    assert!(table.contains("flop balance"), "{table}");
}

#[test]
fn dispatched_build_with_persisted_schwarz_calibration_stays_bitwise() {
    // the coordinator calibrates + writes the table; the spec carries the
    // path, so every worker loads it instead of recalibrating — and the
    // corrected Estimate screening stays bitwise identical end to end
    let cal = std::env::temp_dir()
        .join(format!("matryoshka_dispatch_cal_{}.txt", std::process::id()));
    let _ = std::fs::remove_file(&cal);
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let base = MatryoshkaConfig { schwarz: SchwarzMode::Estimate, ..Default::default() };
    let mut in_process = engine("water", "6-31g*", base.clone());
    let g_ref = in_process.two_electron(&d).unwrap();

    let config = MatryoshkaConfig {
        schwarz: SchwarzMode::Estimate,
        schwarz_cal_path: Some(cal.to_string_lossy().into_owned()),
        dispatch: local_dispatch(2),
        ..base
    };
    let mut e = engine("water", "6-31g*", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "persisted-calibration dispatch diverged");
    assert!(cal.exists(), "coordinator must have written the calibration table");
    let _ = std::fs::remove_file(&cal);
}
