//! Incremental-Fock acceptance tests (ISSUE 8).
//!
//! The bar: with `--incremental` the engine contracts ΔD = D_k − D_{k−1}
//! over the ΔD-surviving chunk subset and accumulates G_k = G_{k−1} + ΔG —
//! the final SCF energy must sit within 1e-9 Ha of the full-rebuild path
//! (and the literature windows), each iteration's G must be bitwise
//! invariant across thread counts AND `--dispatch local:2`, the
//! density-weighted screen must actually shrink the executed chunk set as
//! the SCF converges, and a worker whose re-screen drifts from the
//! coordinator's chunk subset must be refused at the fingerprint check.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::constructor::{
    delta_threshold, filter_plan_by_delta, BlockPlan, PairList, SchwarzMode, ShellDeltaMax,
};
use matryoshka::dispatch::proto::{auth_tag, read_msg, write_msg};
use matryoshka::dispatch::worker::{serve, WorkerOptions};
use matryoshka::dispatch::{DispatchConfig, DispatchMode, JobSpec, Msg, PROTO_VERSION};
use matryoshka::engines::{IncrementalMode, MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::pipeline::{ChunkSchedule, PipelineMode, SchedulePolicy};
use matryoshka::runtime::{BackendKind, LadderMode, NativeBackend};
use matryoshka::runtime::EriBackend;
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn engine(molecule: &str, basis_name: &str, config: MatryoshkaConfig) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

/// A small deterministic symmetric density sequence: k = 0 is the usual
/// test density, later k's perturb it smoothly so every ΔD is nonzero
/// but small — the regime incremental builds live in.
fn density_sequence(n: usize, k: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let base = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            let ripple = 1e-4 * (k as f64) / (1.0 + (i + j) as f64);
            let v = base + ripple;
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn scf(molecule: &str, basis_name: &str, incremental: IncrementalMode) -> (f64, bool) {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    let config = MatryoshkaConfig { incremental, ..Default::default() };
    let mut eng = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
    let res = run_rhf(&mol, &basis, &mut eng, &ScfOptions::default()).unwrap();
    (res.energy, res.converged)
}

#[test]
fn incremental_energy_matches_full_rebuild_water_631gstar() {
    let (full, c0) = scf("water", "6-31g*", IncrementalMode::Off);
    let (inc, c1) = scf("water", "6-31g*", IncrementalMode::On);
    let (cadence, c2) = scf("water", "6-31g*", IncrementalMode::Every(4));
    assert!(c0 && c1 && c2, "all three SCFs must converge");
    assert!((inc - full).abs() < 1e-9, "incremental {inc:.12} vs full {full:.12}");
    assert!((cadence - full).abs() < 1e-9, "every:4 {cadence:.12} vs full {full:.12}");
    // literature RHF/6-31G* water ≈ −76.01 Ha
    assert!((full + 76.01).abs() < 0.01, "water E = {full:.7}");
}

#[test]
fn incremental_energy_matches_full_rebuild_methane_631gstar() {
    let (full, c0) = scf("methane", "6-31g*", IncrementalMode::Off);
    let (inc, c1) = scf("methane", "6-31g*", IncrementalMode::On);
    assert!(c0 && c1, "both SCFs must converge");
    assert!((inc - full).abs() < 1e-9, "incremental {inc:.12} vs full {full:.12}");
    // literature RHF/6-31G* methane ≈ −40.19 Ha
    assert!((full + 40.19).abs() < 0.01, "methane E = {full:.7}");
}

#[test]
fn delta_screen_shrinks_the_executed_chunk_set_as_scf_converges() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let config = MatryoshkaConfig { incremental: IncrementalMode::On, ..Default::default() };
    let mut eng = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
    let res = run_rhf(&mol, &basis, &mut eng, &ScfOptions::default()).unwrap();
    assert!(res.converged);
    let trace = eng.fock_trace();
    assert!(trace.len() >= 3, "need several builds, got {}", trace.len());
    assert!(!trace[0].incremental, "the guess build runs the full schedule");
    let first = trace[0].chunks_executed;
    // the tail of the SCF must run incremental builds (the drift guard may
    // deterministically force an occasional full rebuild, but never pin the
    // engine to the full path)
    assert!(
        trace.iter().rev().take(2).any(|s| s.incremental),
        "no incremental build in the last two iterations"
    );
    let last = trace.iter().rev().find(|s| s.incremental).unwrap();
    assert!(
        last.chunks_executed < first,
        "last build executed {} of iteration 1's {} chunks — the delta screen did nothing",
        last.chunks_executed,
        first
    );
    // a late build screens a nonzero share and records the ΔD it saw
    assert!(last.chunks_screened > 0);
    assert!(last.dd_max > 0.0 && last.dd_max < 1e-2, "late dD max {:.3e}", last.dd_max);
    // every incremental + full split is reflected in the wire metrics too
    let inc = trace.iter().filter(|s| s.incremental).count() as u64;
    assert_eq!(eng.metrics.incremental_builds, inc);
    assert_eq!(eng.metrics.full_builds, trace.len() as u64 - inc);
}

#[test]
fn per_iteration_g_is_bitwise_invariant_across_threads_and_dispatch() {
    // all variants run incremental mode and see the identical density
    // sequence; every per-call G must agree bit for bit
    let base = MatryoshkaConfig {
        incremental: IncrementalMode::On,
        schwarz: SchwarzMode::Estimate,
        ..Default::default()
    };
    let mut variants: Vec<(String, MatryoshkaEngine)> = vec![
        (
            "threads:1".into(),
            engine("water", "6-31g*", MatryoshkaConfig { threads: 1, ..base.clone() }),
        ),
        (
            "threads:3".into(),
            engine("water", "6-31g*", MatryoshkaConfig { threads: 3, ..base.clone() }),
        ),
        (
            "threads:3 lockstep".into(),
            engine(
                "water",
                "6-31g*",
                MatryoshkaConfig {
                    threads: 3,
                    pipeline: PipelineMode::Lockstep,
                    ..base.clone()
                },
            ),
        ),
        (
            "dispatch local:2".into(),
            engine(
                "water",
                "6-31g*",
                MatryoshkaConfig {
                    dispatch: DispatchConfig {
                        mode: DispatchMode::Local(2),
                        worker_bin: Some(worker_bin()),
                        ..Default::default()
                    },
                    ..base.clone()
                },
            ),
        ),
    ];
    let n = variants[0].1.basis.nbf;
    for k in 0..4 {
        let d = density_sequence(n, k);
        let mut reference: Option<Vec<f64>> = None;
        for (label, eng) in variants.iter_mut() {
            let g = eng.two_electron(&d).unwrap();
            match &reference {
                None => reference = Some(g.data().to_vec()),
                Some(want) => assert_eq!(
                    g.data(),
                    want.as_slice(),
                    "iteration {k}: {label} diverged bitwise"
                ),
            }
        }
    }
    // iterations 1..3 ran the delta path everywhere (same trace shape)
    for (label, eng) in &variants {
        let trace = eng.fock_trace();
        assert_eq!(trace.len(), 4, "{label}");
        assert!(!trace[0].incremental, "{label}");
        assert!(trace[1..].iter().all(|s| s.incremental), "{label}");
    }
}

#[test]
fn dispatched_incremental_scf_matches_in_process_bitwise() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();
    let base = MatryoshkaConfig {
        incremental: IncrementalMode::Every(4),
        schwarz: SchwarzMode::Estimate,
        ..Default::default()
    };
    let mut local = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), base.clone()).unwrap();
    let res_local = run_rhf(&mol, &basis, &mut local, &opts).unwrap();
    let mut dispatched = MatryoshkaEngine::new(
        basis.clone(),
        Path::new("unused"),
        MatryoshkaConfig {
            dispatch: DispatchConfig {
                mode: DispatchMode::Local(2),
                worker_bin: Some(worker_bin()),
                ..Default::default()
            },
            ..base
        },
    )
    .unwrap();
    let res_disp = run_rhf(&mol, &basis, &mut dispatched, &opts).unwrap();
    assert!(res_local.converged && res_disp.converged);
    // bitwise: the dispatched delta builds fold the same shards through
    // the same merge tree the in-process path uses
    assert_eq!(res_local.energy.to_bits(), res_disp.energy.to_bits());
    assert_eq!(res_local.iterations, res_disp.iterations);
}

#[test]
fn stored_mode_refuses_incremental() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let config = MatryoshkaConfig {
        stored: true,
        incremental: IncrementalMode::On,
        ..Default::default()
    };
    let err = MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap_err().to_string();
    assert!(err.contains("--stored with --incremental"), "{err}");
}

#[test]
fn incremental_mode_parses_and_rejects() {
    assert_eq!(IncrementalMode::parse("off").unwrap(), IncrementalMode::Off);
    assert_eq!(IncrementalMode::parse("on").unwrap(), IncrementalMode::On);
    assert_eq!(IncrementalMode::parse("every:8").unwrap(), IncrementalMode::Every(8));
    for bad in ["", "ON", "every", "every:", "every:1", "every:x", "delta"] {
        assert!(IncrementalMode::parse(bad).is_err(), "{bad:?}");
    }
    assert_eq!(IncrementalMode::Every(8).describe(), "every:8");
}

#[test]
fn worker_refuses_a_hand_shrunk_chunk_subset_at_the_fingerprint_check() {
    // Round-trip a delta-screened Build over the real wire against a real
    // worker, but fingerprint a hand-shrunk chunk subset (one surviving
    // block emptied) — the worker re-runs the screen over the shipped ΔD,
    // rebuilds the honest schedule, and must refuse at the fingerprint
    // check before executing anything.
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let nbf = basis.nbf;
    let threshold = 1e-10;
    let spec = JobSpec {
        title: "delta fingerprint test".into(),
        basis: basis.clone(),
        threshold,
        tile: 64,
        clustered: true,
        greedy_path: true,
        fixed_batch: 512,
        schwarz: SchwarzMode::Estimate,
        backend: BackendKind::Native,
        ladder: LadderMode::Elastic,
        eri_strategy: Default::default(),
        digest: Default::default(),
        working_set_bytes: 4 << 20,
        wide_opb_max: 4.0,
        threads: 1,
        pipeline: PipelineMode::Staged,
        artifact_dir: "unused".into(),
        schwarz_cal_path: None,
    };

    // coordinator-side replica of the worker's screen: same plan, same
    // ΔD, same tightened threshold
    let pairs = PairList::build_with_mode(&basis, threshold, SchwarzMode::Estimate);
    let plan = BlockPlan::build(&pairs, threshold, 64, true);
    let mut delta = Matrix::zeros(nbf, nbf);
    for i in 0..nbf {
        for j in 0..nbf {
            let v = 1e-6 / (1.0 + (i as f64 - j as f64).abs()).powi(2);
            *delta.at_mut(i, j) = v;
            *delta.at_mut(j, i) = v;
        }
    }
    let dmax = ShellDeltaMax::build(&basis, &delta);
    let (filtered, stats) = filter_plan_by_delta(&plan, &pairs, &dmax, delta_threshold(threshold));
    assert!(stats.surviving > 0 && stats.screened > 0, "screen must split the stream: {stats:?}");
    let manifest = NativeBackend::with_kpair(basis.max_kpair()).manifest().clone();
    let policy = SchedulePolicy::default();
    let snapshot: BTreeMap<_, _> = BTreeMap::new();
    let honest =
        ChunkSchedule::build(&filtered, &manifest, &snapshot, &policy, &pairs, nbf).unwrap();

    // hand-shrink the subset: empty one surviving block's quads
    let mut shrunk = filtered.clone();
    let victim = shrunk.blocks.iter().position(|b| !b.quads.is_empty()).unwrap();
    shrunk.blocks[victim].quads.clear();
    let forged = ChunkSchedule::build(&shrunk, &manifest, &snapshot, &policy, &pairs, nbf).unwrap();
    assert_ne!(honest.fingerprint(), forged.fingerprint());

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let worker = std::thread::spawn(move || -> anyhow::Result<()> {
        let (stream, _) = listener.accept()?;
        let mut r = BufReader::new(stream.try_clone()?);
        let mut w = BufWriter::new(stream);
        serve(&mut r, &mut w, &WorkerOptions::default())
    });
    let stream = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = BufWriter::new(stream);
    let hello_nonce = match read_msg(&mut r).unwrap() {
        Msg::Hello { version, nonce } => {
            assert_eq!(version, PROTO_VERSION);
            nonce
        }
        other => panic!("expected Hello, got {}", other.kind()),
    };
    write_msg(
        &mut w,
        &Msg::Setup { spec: Box::new(spec), nonce: 3, auth: auth_tag("", hello_nonce) },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::SetupAck { nbf: got, .. } => assert_eq!(got, nbf),
        other => panic!("expected SetupAck, got {}", other.kind()),
    }
    // honest fingerprint + honest ΔD round-trips: the worker's re-screen
    // reproduces the coordinator's chunk subset exactly
    write_msg(
        &mut w,
        &Msg::Build {
            iter: 1,
            fingerprint: honest.fingerprint(),
            delta_screen: true,
            snapshot: snapshot.clone(),
            density: delta.clone(),
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::BuildAck { iter, fingerprint } => {
            assert_eq!(iter, 1);
            assert_eq!(fingerprint, honest.fingerprint());
        }
        other => panic!("expected BuildAck, got {}", other.kind()),
    }
    // forged fingerprint (the hand-shrunk subset) must be refused
    write_msg(
        &mut w,
        &Msg::Build {
            iter: 2,
            fingerprint: forged.fingerprint(),
            delta_screen: true,
            snapshot,
            density: delta,
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { fatal, message } => {
            assert!(fatal, "a fingerprint refusal is a fatal protocol error");
            assert!(message.contains("fingerprint mismatch"), "{message}");
            assert!(message.contains("refusing to execute"), "{message}");
        }
        other => panic!("expected Error, got {}", other.kind()),
    }
    let err = worker.join().unwrap().unwrap_err().to_string();
    assert!(err.contains("fingerprint mismatch"), "{err}");
}
