//! d-shell pipeline acceptance tests (ISSUE 2):
//!
//! * native backend vs the `eri_shell_quartet` oracle on (ds|ss), (dd|ss)
//!   and (dd|dd) quartets, for both evaluator strategies;
//! * exact Schwarz bounds remain true upper bounds with d shells present
//!   (screening can never drop a quad above threshold);
//! * 6-31G* golden SCF energies: the native Matryoshka engine must match
//!   the independent reference engine to ≤ 1e-8 on water and methane, and
//!   both must land in the literature windows;
//! * bitwise 1-vs-N-thread determinism re-asserted on a 6-31G* molecule.

use std::path::Path;

use matryoshka::basis::{build_basis, BasisSet, Shell};
use matryoshka::constructor::PairList;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine, ReferenceEngine};
use matryoshka::integrals::{eri_shell_quartet, EriRefStats};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::runtime::{EriBackend, EriEvalStrategy, NativeBackend};
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};

fn shell(l: u8, exps: &[f64], coefs: &[f64], center: [f64; 3], first_bf: usize) -> Shell {
    let mut sh = Shell::new(l, exps.to_vec(), coefs.to_vec(), center, 0, first_bf);
    sh.normalize();
    sh
}

/// Two contracted d shells and two s shells on four centers.
fn d_test_basis() -> BasisSet {
    let d1 = shell(2, &[0.9, 0.35], &[0.7, 0.4], [0.1, -0.2, 0.3], 0);
    let d2 = shell(2, &[1.4, 0.5], &[0.5, 0.6], [-0.6, 0.5, 0.0], 6);
    let s1 = shell(0, &[1.2], &[1.0], [0.8, 0.4, -0.2], 12);
    let s2 = shell(0, &[0.6], &[1.0], [0.0, -0.9, 0.7], 13);
    BasisSet { shells: vec![d1, d2, s1, s2], nbf: 14 }
}

fn pair_index(pairs: &PairList, si: usize, sj: usize) -> usize {
    pairs
        .pairs
        .iter()
        .position(|p| (p.si, p.sj) == (si, sj) || (p.si, p.sj) == (sj, si))
        .expect("pair present")
}

/// Run one (bra pair, ket pair) quad through the backend's first-rung
/// variant and return (values, ncomp).
fn chunk_eri(
    backend: &NativeBackend,
    pairs: &PairList,
    bi: usize,
    ki: usize,
) -> (Vec<f64>, usize) {
    let bra = &pairs.pairs[bi];
    let ket = &pairs.pairs[ki];
    assert!(bra.class >= ket.class, "test must pass canonical pair order");
    let class = (bra.class.0, bra.class.1, ket.class.0, ket.class.1);
    let variant = backend.manifest().ladder(class)[0].clone();
    let (b, kb, kk) = (variant.batch, variant.kpair_bra, variant.kpair_ket);
    assert_eq!(kb, pairs.kpair);

    let mut bp = vec![0.0; b * kb * 5];
    let mut bg = vec![0.0; b * 6];
    let mut kp = vec![0.0; b * kk * 5];
    let mut kg = vec![0.0; b * 6];
    for r in 0..b {
        for k in 0..kb {
            bp[(r * kb + k) * 5] = 1.0;
        }
        for k in 0..kk {
            kp[(r * kk + k) * 5] = 1.0;
        }
    }
    bp[..kb * 5].copy_from_slice(&bra.prim);
    kp[..kk * 5].copy_from_slice(&ket.prim);
    bg[..6].copy_from_slice(&bra.geom);
    kg[..6].copy_from_slice(&ket.geom);

    let exec = backend.execute_eri(&variant, &bp, &bg, &kp, &kg).unwrap();
    // padding rows must stay exact zeros with d shells too
    assert!(exec.values[exec.ncomp..].iter().all(|&v| v == 0.0));
    (exec.values, exec.ncomp)
}

#[test]
fn d_class_chunks_match_shell_quartet_oracle() {
    let basis = d_test_basis();
    let pairs = PairList::build(&basis, 1e-14);
    let p_dd = pair_index(&pairs, 0, 1);
    let p_ds = pair_index(&pairs, 0, 2);
    let p_ss = pair_index(&pairs, 2, 3);

    for strategy in [EriEvalStrategy::Tables, EriEvalStrategy::Recursion] {
        let backend = NativeBackend::with_options(pairs.kpair, strategy);
        // (ds|ss), (dd|ss), (dd|dd)
        for (bi, ki) in [(p_ds, p_ss), (p_dd, p_ss), (p_dd, p_dd)] {
            let (values, ncomp) = chunk_eri(&backend, &pairs, bi, ki);
            let bra = &pairs.pairs[bi];
            let ket = &pairs.pairs[ki];
            let mut stats = EriRefStats::default();
            let oracle = eri_shell_quartet(
                &basis.shells[bra.si],
                &basis.shells[bra.sj],
                &basis.shells[ket.si],
                &basis.shells[ket.sj],
                &mut stats,
            );
            assert_eq!(ncomp, oracle.len());
            let mut max_abs = 0.0f64;
            for (c, (got, want)) in values[..ncomp].iter().zip(&oracle).enumerate() {
                max_abs = max_abs.max(want.abs());
                assert!(
                    (got - want).abs() < 1e-10,
                    "{} quad ({bi},{ki}) comp {c}: {got} vs {want}",
                    strategy.name()
                );
            }
            // the block is not trivially zero
            assert!(max_abs > 1e-4, "oracle block suspiciously small: {max_abs}");
        }
    }
}

#[test]
fn exact_schwarz_bounds_hold_with_d_shells() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let pairs = PairList::build(&basis, 1e-14); // exact mode
    let mut stats = EriRefStats::default();
    // |(ab|cd)| <= Q_ab * Q_cd for every pair combination — a quad whose
    // true magnitude exceeds the threshold can therefore never be dropped
    for (bi, bra) in pairs.pairs.iter().enumerate() {
        for ket in pairs.pairs.iter().skip(bi) {
            let block = eri_shell_quartet(
                &basis.shells[bra.si],
                &basis.shells[bra.sj],
                &basis.shells[ket.si],
                &basis.shells[ket.sj],
                &mut stats,
            );
            let max_abs = block.iter().fold(0.0f64, |m, v| m.max(v.abs()));
            let bound = bra.schwarz * ket.schwarz;
            assert!(
                max_abs <= bound * (1.0 + 1e-10),
                "pair ({},{})x({},{}): |block| {max_abs:.3e} > bound {bound:.3e}",
                bra.si,
                bra.sj,
                ket.si,
                ket.sj
            );
        }
    }
}

#[test]
fn table_and_recursion_strategies_agree_on_631gs_g_matrix() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let n = basis.nbf;
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    let build = |strategy: EriEvalStrategy| {
        let backend = Box::new(NativeBackend::with_options(basis.max_kpair(), strategy));
        let config = MatryoshkaConfig { threshold: 1e-12, ..Default::default() };
        let mut e = MatryoshkaEngine::with_backend(basis.clone(), backend, config).unwrap();
        e.two_electron(&d).unwrap()
    };
    let g_tab = build(EriEvalStrategy::Tables);
    let g_rec = build(EriEvalStrategy::Recursion);
    let diff = g_tab.diff_norm(&g_rec);
    assert!(diff < 1e-10, "strategy mismatch: ||dG|| = {diff:.3e}");
}

fn golden_631gs(molecule: &str, literature: f64, window: f64) {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let opts = ScfOptions::default();

    let mut reference = ReferenceEngine::new(basis.clone(), 1e-10);
    let res_ref = run_rhf(&mol, &basis, &mut reference, &opts).unwrap();

    let config = MatryoshkaConfig { threshold: 1e-10, stored: true, ..Default::default() };
    let mut engine = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
    let res = run_rhf(&mol, &basis, &mut engine, &opts).unwrap();

    assert!(res_ref.converged, "{molecule}: reference SCF did not converge");
    assert!(res.converged, "{molecule}: native SCF did not converge");
    assert!(
        (res.energy - res_ref.energy).abs() < 1e-8,
        "{molecule}: matryoshka {} vs reference {}",
        res.energy,
        res_ref.energy
    );
    assert!(
        (res.energy - literature).abs() < window,
        "{molecule}: E = {:.7}, literature ≈ {literature}",
        res.energy
    );
}

#[test]
fn water_631gs_golden_scf_energy() {
    // RHF/6-31G* water ≈ −76.01 Ha (Cartesian d functions)
    golden_631gs("water", -76.01, 0.05);
}

#[test]
fn methane_631gs_golden_scf_energy() {
    // RHF/6-31G* methane ≈ −40.19 Ha (Cartesian d functions)
    golden_631gs("methane", -40.19, 0.05);
}

#[test]
fn one_thread_and_n_thread_631gs_builds_agree_bitwise() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let n = basis.nbf;
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    let build = |threads: usize| {
        let config = MatryoshkaConfig { threshold: 1e-10, threads, ..Default::default() };
        let mut e = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
        e.two_electron(&d).unwrap()
    };
    let g1 = build(1);
    for threads in [2, 6] {
        let gn = build(threads);
        assert_eq!(
            g1.data(),
            gn.data(),
            "{threads}-thread 6-31G* build diverged from the 1-thread build"
        );
    }
}
