//! Backend parity + parallel-determinism acceptance tests.
//!
//! * The native backend's G matrices must match the independent
//!   `ReferenceEngine` oracle (a different MD formulation) to ≤ 1e-8 on
//!   water and benzene.
//! * A 1-thread and an N-thread Fock build must agree **bitwise**: the
//!   deterministic accumulator merge (`fock::accumulate`) fixes the
//!   floating-point summation tree independently of the thread count.

use std::path::Path;

use matryoshka::basis::build_basis;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine, ReferenceEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::scf::FockEngine;

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn native_engine(molecule: &str, threshold: f64, threads: usize) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let config = MatryoshkaConfig { threshold, threads, ..Default::default() };
    // the native backend needs no artifacts directory
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

fn parity_on(molecule: &str) {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut reference = ReferenceEngine::new(basis.clone(), 1e-14);
    let g_ref = reference.two_electron(&d).unwrap();

    let mut engine = native_engine(molecule, 1e-14, 0);
    let g = engine.two_electron(&d).unwrap();

    let diff = g.diff_norm(&g_ref);
    assert!(diff < 1e-8, "{molecule}: ||G_native − G_ref|| = {diff:.3e}");
}

#[test]
fn native_backend_matches_reference_engine_on_water() {
    parity_on("water");
}

#[test]
fn native_backend_matches_reference_engine_on_benzene() {
    parity_on("benzene");
}

#[test]
fn one_thread_and_n_thread_fock_builds_agree_bitwise() {
    let mol = library::by_name("benzene").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let mut serial = native_engine("benzene", 1e-10, 1);
    let g1 = serial.two_electron(&d).unwrap();
    assert_eq!(serial.threads(), 1);

    for threads in [2, 5, 8] {
        let mut parallel = native_engine("benzene", 1e-10, threads);
        let gn = parallel.two_electron(&d).unwrap();
        // bitwise, not within-epsilon: the merge tree is thread-invariant
        assert_eq!(
            g1.data(),
            gn.data(),
            "{threads}-thread build diverged from the 1-thread build"
        );
    }
}

#[test]
fn parallel_build_reports_worker_count_and_backend() {
    let engine = native_engine("water", 1e-10, 3);
    assert_eq!(engine.threads(), 3);
    assert_eq!(engine.backend_name(), "native");
    assert_eq!(engine.parallelism(), 3);
}

#[test]
fn stored_mode_parallel_digest_is_bitwise_stable_too() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);

    let build = |threads: usize| {
        let config = MatryoshkaConfig {
            threshold: 1e-12,
            stored: true,
            threads,
            ..Default::default()
        };
        let mut e = MatryoshkaEngine::new(basis.clone(), Path::new("unused"), config).unwrap();
        let _warm = e.two_electron(&d).unwrap(); // fills the cache
        e.two_electron(&d).unwrap() // digest-only fast path
    };
    let g1 = build(1);
    let g4 = build(4);
    assert_eq!(g1.data(), g4.data());
}
