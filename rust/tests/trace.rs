//! Observability acceptance tests (ISSUE 10).
//!
//! The bar: tracing must be bitwise invisible — G and the whole SCF
//! trajectory identical with the sink enabled or disabled, in-process
//! and across `--dispatch local:2` — while an enabled sink produces a
//! structurally valid Chrome trace: spans that nest properly per track,
//! a single timeline holding the coordinator (pid 0) plus every
//! dispatched worker (pid w+1) clock-aligned, and `fock_build` span ids
//! that cross-reference the engine's per-iteration [`FockBuildStats`].

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::dispatch::{DispatchConfig, DispatchMode};
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::linalg::Matrix;
use matryoshka::molecule::library;
use matryoshka::scf::{run_rhf, FockEngine, ScfOptions};
use matryoshka::trace::{chrome, EventKind, TraceExport, TraceSink};

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_matryoshka"))
}

fn test_density(n: usize) -> Matrix {
    let mut d = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = 0.3 / (1.0 + (i as f64 - j as f64).abs());
            *d.at_mut(i, j) = v;
            *d.at_mut(j, i) = v;
        }
    }
    d
}

fn engine(molecule: &str, basis_name: &str, config: MatryoshkaConfig) -> MatryoshkaEngine {
    let mol = library::by_name(molecule).unwrap();
    let basis = build_basis(&mol, basis_name).unwrap();
    MatryoshkaEngine::new(basis, Path::new("unused"), config).unwrap()
}

fn span_names(export: &TraceExport) -> HashSet<String> {
    export
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Span)
        .map(|e| e.name.clone())
        .collect()
}

/// Spans on one `(pid, tid)` track came off a call stack, so any two must
/// either nest or be disjoint — never partially overlap.
fn assert_stack_nesting(export: &TraceExport) {
    let mut per_track: std::collections::BTreeMap<(u32, u32), Vec<(i64, i64, &str)>> =
        std::collections::BTreeMap::new();
    for e in &export.events {
        if e.kind == EventKind::Span {
            per_track
                .entry((e.pid, e.tid))
                .or_default()
                .push((e.ts_us, e.ts_us + e.dur_us as i64, &e.name));
        }
    }
    for ((pid, tid), spans) in &per_track {
        for (i, a) in spans.iter().enumerate() {
            for b in spans.iter().skip(i + 1) {
                let disjoint = a.1 <= b.0 || b.1 <= a.0;
                let a_in_b = b.0 <= a.0 && a.1 <= b.1;
                let b_in_a = a.0 <= b.0 && b.1 <= a.1;
                assert!(
                    disjoint || a_in_b || b_in_a,
                    "spans {a:?} and {b:?} partially overlap on track ({pid}, {tid})"
                );
            }
        }
    }
}

#[test]
fn tracing_is_bitwise_invisible_and_spans_nest_in_process() {
    // 6-31G* water exercises d classes, both stage shapes, and multiple
    // merge units — the full span surface
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let mut plain = engine("water", "6-31g*", MatryoshkaConfig::default());
    let g_ref = plain.two_electron(&d).unwrap();

    let sink = TraceSink::enabled();
    let config = MatryoshkaConfig { trace: sink.clone(), ..Default::default() };
    let mut traced = engine("water", "6-31g*", config);
    let g = traced.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "enabling tracing changed G");

    let export = sink.export();
    let names = span_names(&export);
    for expected in [
        "schwarz_screen",
        "block_plan",
        "schedule_build",
        "fock_build",
        "unit",
        "gather",
        "digest",
        "execute",
        "merge_partials",
    ] {
        assert!(names.contains(expected), "missing span {expected:?}; got {names:?}");
    }
    assert_stack_nesting(&export);
    // every execute span carries its evaluator; every digest its strategy
    for e in &export.events {
        if e.kind == EventKind::Span && (e.name == "execute" || e.name == "digest") {
            assert!(
                e.args.iter().any(|(k, _)| k == "strategy"),
                "{} span missing strategy arg: {:?}",
                e.name,
                e.args
            );
        }
    }
}

#[test]
fn scf_trajectory_is_identical_with_tracing_and_spans_cross_reference() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();

    let mut plain = engine("water", "sto-3g", MatryoshkaConfig::default());
    let res_ref = run_rhf(&mol, &basis, &mut plain, &ScfOptions::default()).unwrap();
    assert!(res_ref.converged);

    let sink = TraceSink::enabled();
    let config = MatryoshkaConfig { trace: sink.clone(), ..Default::default() };
    let mut traced = engine("water", "sto-3g", config);
    let opts = ScfOptions { trace: sink.clone(), ..Default::default() };
    let res = run_rhf(&mol, &basis, &mut traced, &opts).unwrap();

    assert_eq!(res.energy, res_ref.energy, "tracing changed the SCF energy");
    assert_eq!(res.iterations, res_ref.iterations);
    assert_eq!(res.energy_trace, res_ref.energy_trace);

    let export = sink.export();
    let names = span_names(&export);
    assert!(names.contains("scf_iteration"), "{names:?}");
    assert!(names.contains("diis_extrapolate"), "{names:?}");
    // each recorded Fock build points at a real fock_build span id
    let span_ids: HashSet<u64> = export
        .events
        .iter()
        .filter(|e| e.name == "fock_build")
        .map(|e| e.id)
        .collect();
    let builds = traced.fock_trace();
    assert!(!builds.is_empty());
    for b in builds {
        assert!(b.span != 0, "FockBuildStats.span unset with tracing on");
        assert!(span_ids.contains(&b.span), "span {} has no fock_build event", b.span);
    }
}

#[test]
fn dispatched_trace_merges_both_workers_onto_the_coordinator_timeline() {
    // the ISSUE 10 acceptance case: a dispatched 6-31G* water build with
    // tracing must keep G bitwise AND produce one Chrome JSON holding
    // pid 0 (coordinator) plus pids 1 and 2 (both workers), clock-aligned
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "6-31g*").unwrap();
    let d = test_density(basis.nbf);

    let mut plain = engine("water", "6-31g*", MatryoshkaConfig::default());
    let g_ref = plain.two_electron(&d).unwrap();

    let sink = TraceSink::enabled();
    let config = MatryoshkaConfig {
        trace: sink.clone(),
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "6-31g*", config);
    let g = e.two_electron(&d).unwrap();
    assert_eq!(g_ref.data(), g.data(), "traced dispatched G diverged");
    drop(e); // shut the fleet down before inspecting the merged timeline

    let export = sink.export();
    let end_us = sink.now_us() as i64;
    let pids: HashSet<u32> = export.events.iter().map(|ev| ev.pid).collect();
    assert!(pids.contains(&0), "coordinator events missing: {pids:?}");
    assert!(
        pids.contains(&1) && pids.contains(&2),
        "both workers must appear on the timeline: {pids:?}"
    );
    // clock alignment: every remote timestamp maps into the coordinator's
    // clock window (non-negative, not in the future)
    for ev in &export.events {
        assert!(
            ev.ts_us >= 0 && ev.ts_us <= end_us,
            "event {:?} (pid {}) off the unified timeline: ts {}us, end {}us",
            ev.name,
            ev.pid,
            ev.ts_us,
            end_us
        );
    }
    // worker pipeline spans and coordinator dispatch events coexist
    assert!(
        export
            .events
            .iter()
            .any(|ev| ev.pid > 0 && ev.kind == EventKind::Span && ev.name == "unit"),
        "no worker unit spans crossed the wire"
    );
    assert!(
        export.events.iter().any(|ev| ev.pid == 0 && ev.name == "dispatch_build"),
        "no coordinator dispatch_build span"
    );
    assert!(
        export.events.iter().any(|ev| ev.pid == 0 && ev.name == "run_handout"),
        "no run_handout instants"
    );
    // every worker track is named after its link label
    assert!(
        export.tracks.iter().any(|((pid, _), name)| *pid > 0 && name.contains("local:")),
        "worker tracks not labeled: {:?}",
        export.tracks
    );
    assert_stack_nesting(&export);

    // the file round-trip the CLI performs: write, re-read, validate
    let path = std::env::temp_dir()
        .join(format!("matryoshka_trace_{}.json", std::process::id()));
    chrome::write_chrome(&path, &export).unwrap();
    let (_doc, summary) = chrome::read_chrome(&path).unwrap();
    assert_eq!(summary.pids, vec![0, 1, 2], "{summary:?}");
    assert!(summary.has_event("fock_build"), "{summary:?}");
    assert!(summary.has_event("execute"), "{summary:?}");
    assert!(summary.spans > 0 && summary.metadata > 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn disabled_sink_records_nothing_across_a_dispatched_build() {
    // dispatch with tracing off: the JobSpec flag stays false, workers
    // ship no Trace frames, and the export is empty
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let d = test_density(basis.nbf);
    let sink = TraceSink::disabled();
    let config = MatryoshkaConfig {
        trace: sink.clone(),
        dispatch: DispatchConfig {
            mode: DispatchMode::Local(2),
            worker_bin: Some(worker_bin()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut e = engine("water", "sto-3g", config);
    e.two_electron(&d).unwrap();
    let export = sink.export();
    assert!(export.events.is_empty() && export.tracks.is_empty());
}
