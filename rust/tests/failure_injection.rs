//! Failure injection: the coordinator must turn broken artifacts,
//! truncated manifests and impossible configurations into clean errors,
//! never silent corruption.
//!
//! Manifest-parsing and engine-level failures run on every build; the
//! PJRT-runtime failures (broken HLO files etc.) only compile with
//! `--features pjrt` since the runtime itself is feature-gated.

use std::path::{Path, PathBuf};

use matryoshka::basis::build_basis;
use matryoshka::engines::{MatryoshkaConfig, MatryoshkaEngine};
use matryoshka::molecule::{library, Atom, Molecule};
use matryoshka::runtime::{
    create_backend, BackendKind, EriBackend, EriEvalStrategy, LadderMode, Manifest,
};
use matryoshka::scf::{run_rhf, ScfOptions};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("matryoshka_fail_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn corrupt_manifest_line_is_rejected_with_line_number() {
    let d = tmpdir("badline");
    std::fs::write(d.join("manifest.txt"), "eri_x 0 0 0 nonsense\n").unwrap();
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("line 1"), "{err}");
}

#[test]
fn empty_manifest_is_rejected() {
    let d = tmpdir("empty");
    std::fs::write(d.join("manifest.txt"), "# nothing here\n").unwrap();
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("no artifacts"), "{err}");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn requesting_pjrt_without_the_feature_is_a_clean_error() {
    let err = create_backend(BackendKind::Pjrt, Path::new("anywhere"), 9, 4, LadderMode::default(), EriEvalStrategy::default()).unwrap_err();
    assert!(err.to_string().contains("pjrt"), "{err}");
}

#[test]
fn native_backend_never_needs_an_artifact_dir() {
    let backend =
        create_backend(BackendKind::Native, Path::new("/nonexistent/artifacts"), 9, 4, LadderMode::default(), EriEvalStrategy::default()).unwrap();
    assert_eq!(backend.name(), "native");
}

#[test]
fn uncataloged_class_fails_at_engine_construction_not_mid_build() {
    // regression: a class absent from the catalog used to reach
    // ClassTuner with an empty ladder and panic with index-out-of-bounds;
    // now engine construction itself reports "no kernel variant"
    use matryoshka::basis::{BasisSet, Shell};
    let mut f_shell = Shell::new(3, vec![0.7], vec![1.0], [0.0; 3], 0, 0);
    f_shell.normalize();
    let mut s_shell = Shell::new(0, vec![1.1], vec![1.0], [0.0, 0.0, 1.5], 0, 10);
    s_shell.normalize();
    let basis = BasisSet { shells: vec![f_shell, s_shell], nbf: 11 };
    let err = MatryoshkaEngine::new(basis, Path::new("unused"), MatryoshkaConfig::default())
        .err()
        .expect("f shells are beyond the native catalog")
        .to_string();
    assert!(err.contains("no kernel variant"), "{err}");
    assert!(err.contains('3'), "class should be named: {err}");
}

#[test]
fn odd_electron_molecule_is_rejected_before_any_work() {
    let mol = Molecule::new("radical", vec![Atom { z: 1, pos: [0.0; 3] }]);
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let mut engine =
        MatryoshkaEngine::new(basis.clone(), Path::new("unused"), MatryoshkaConfig::default())
            .unwrap();
    let err = run_rhf(&mol, &basis, &mut engine, &ScfOptions::default());
    assert!(err.unwrap_err().to_string().contains("closed shell"));
}

#[test]
fn more_electrons_than_basis_functions_is_rejected() {
    // O2 with all shells except two s shells stripped is impossible
    let mol = Molecule::new(
        "overfull",
        vec![
            Atom { z: 8, pos: [0.0; 3] },
            Atom { z: 8, pos: [0.0, 0.0, 2.0] },
        ],
    );
    let mut basis = build_basis(&mol, "sto-3g").unwrap();
    basis.shells.truncate(2);
    basis.nbf = 2;
    let mut engine =
        MatryoshkaEngine::new(basis.clone(), Path::new("unused"), MatryoshkaConfig::default())
            .unwrap();
    let err = run_rhf(&mol, &basis, &mut engine, &ScfOptions::default());
    assert!(err.unwrap_err().to_string().contains("occupied"), "expected occupancy error");
}

#[test]
fn zero_iteration_budget_reports_not_converged() {
    let mol = library::by_name("water").unwrap();
    let basis = build_basis(&mol, "sto-3g").unwrap();
    let mut engine =
        MatryoshkaEngine::new(basis.clone(), Path::new("unused"), MatryoshkaConfig::default())
            .unwrap();
    let opts = ScfOptions { max_iterations: 1, ..Default::default() };
    let res = run_rhf(&mol, &basis, &mut engine, &opts).unwrap();
    assert!(!res.converged);
    assert_eq!(res.iterations, 1);
}

/// PJRT-runtime failure injection (feature-gated with the runtime).
#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use super::*;
    use matryoshka::linalg::Matrix;
    use matryoshka::runtime::Runtime;
    use matryoshka::scf::FockEngine;

    fn artifact_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    /// Build a Runtime, or skip the test (return None) when the vendored
    /// xla *stub* is linked instead of a real PJRT runtime — the stub
    /// fails at client construction, before the error path under test.
    fn runtime_or_skip(dir: &Path) -> Option<Runtime> {
        match Runtime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) if e.to_string().contains("xla stub") => {
                eprintln!("SKIP: vendored xla stub — no real PJRT runtime linked");
                None
            }
            Err(e) => panic!("manifest itself must parse: {e}"),
        }
    }

    #[test]
    fn manifest_pointing_at_missing_hlo_file_fails_at_execution_time() {
        let d = tmpdir("missing_hlo");
        std::fs::write(
            d.join("manifest.txt"),
            "eri_ssss_b32 0 0 0 0 32 9 9 1 0 1 0 5 900.0 800.0 greedy nowhere.hlo.txt\n",
        )
        .unwrap();
        let Some(mut rt) = runtime_or_skip(&d) else { return };
        let v = rt.manifest.ladder((0, 0, 0, 0))[0].clone();
        let bp = vec![1.0; 32 * 9 * 5];
        let bg = vec![0.0; 32 * 6];
        let err = rt.execute_eri(&v, &bp, &bg, &bp.clone(), &bg.clone());
        assert!(err.is_err(), "missing artifact must error, not crash");
    }

    #[test]
    fn garbage_hlo_text_is_a_compile_error_not_a_crash() {
        let d = tmpdir("garbage_hlo");
        std::fs::write(d.join("kernel.hlo.txt"), "this is not HLO at all").unwrap();
        std::fs::write(
            d.join("manifest.txt"),
            "eri_ssss_b32 0 0 0 0 32 9 9 1 0 1 0 5 900.0 800.0 greedy kernel.hlo.txt\n",
        )
        .unwrap();
        let Some(mut rt) = runtime_or_skip(&d) else { return };
        let v = rt.manifest.ladder((0, 0, 0, 0))[0].clone();
        let bp = vec![1.0; 32 * 9 * 5];
        let bg = vec![0.0; 32 * 6];
        assert!(rt.execute_eri(&v, &bp, &bg, &bp.clone(), &bg.clone()).is_err());
    }

    #[test]
    fn engine_with_missing_class_artifact_reports_the_class() {
        // manifest only covers ssss; a molecule with p shells must fail loudly
        let Some(real) = artifact_dir() else { return };
        let d = tmpdir("only_ssss");
        // copy just the ssss artifact + a pruned manifest
        let full = std::fs::read_to_string(real.join("manifest.txt")).unwrap();
        let kept: Vec<&str> = full
            .lines()
            .filter(|l| l.starts_with('#') || (l.contains(" 0 0 0 0 ") && l.contains("greedy")))
            .collect();
        for line in &kept {
            if line.starts_with('#') {
                continue;
            }
            let file = line.split_whitespace().last().unwrap();
            std::fs::copy(real.join(file), d.join(file)).unwrap();
        }
        std::fs::write(d.join("manifest.txt"), kept.join("\n") + "\n").unwrap();

        let mol = library::by_name("water").unwrap(); // O has p shells
        let basis = build_basis(&mol, "sto-3g").unwrap();
        let config = MatryoshkaConfig { backend: BackendKind::Pjrt, ..Default::default() };
        let mut engine = match MatryoshkaEngine::new(basis.clone(), &d, config) {
            Ok(e) => e,
            Err(e) if e.to_string().contains("xla stub") => {
                eprintln!("SKIP: vendored xla stub — no real PJRT runtime linked");
                return;
            }
            Err(e) => panic!("engine construction: {e}"),
        };
        let density = Matrix::identity(basis.nbf);
        let err = engine.two_electron(&density).unwrap_err().to_string();
        assert!(err.contains("variant") || err.contains("class"), "{err}");
    }
}
