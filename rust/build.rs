//! Runs the graph-compiler kernel generator at build time and writes the
//! straight-line per-class ERI kernels to `$OUT_DIR`; the crate pulls
//! them in via `include!` from `runtime::backend::kernels`.  The same
//! generator module is also compiled into the crate so the `matryoshka
//! codegen` subcommand can re-render the source for the committed
//! snapshot and the CI drift check.

#[path = "src/runtime/backend/kernels/codegen.rs"]
mod codegen;

use std::path::Path;

fn main() {
    println!("cargo:rerun-if-changed=src/runtime/backend/kernels/codegen.rs");
    let out_dir = std::env::var("OUT_DIR").expect("OUT_DIR not set");
    let path = Path::new(&out_dir).join("eri_kernels_generated.rs");
    let source = codegen::generated_source();
    // Only rewrite on change so incremental builds stay incremental.
    if std::fs::read_to_string(&path).map(|old| old == source) != Ok(true) {
        std::fs::write(&path, source).expect("write generated kernels");
    }
}
